"""The CPSL device worker process.

One worker = one wireless device. Lifecycle:

  1. dial the server (retry/backoff), REGISTER, receive the PLAN
     (cut layer, seeds, round layout, data spec);
  2. rebuild its world deterministically from the plan — the synthetic
     dataset + non-IID shards (``data.synthetic``) and the device-side
     split model — so nothing bulky ships at startup;
  3. optional warmup: compile the forward/backward jits on dummy
     params/batches, then READY (keeps measured QoS clean of jit time);
  4. serve CLUSTER_STARTs: for each local epoch draw the same batch the
     in-process ``CPSLDataset.cluster_batch`` would draw (same
     ``batch_seed`` stream, same member order — bit-exactness), run the
     forward, ship SMASHED, await GRAD (timeout + exponential-backoff
     resend), run backward + optimizer step; after L epochs upload the
     device model (AGG) with piggybacked QoS records and await AGG_ACK.

The numerics are the *decomposed* protocol-step jits — device forward
(``device_apply``) and per-client backward (vjp + optimizer) — which
reproduce the monolithic ``CPSL._protocol_step`` bit-exactly on XLA:CPU
(pinned by tests/test_rt_loopback.py).

Robustness: an ERROR reply (server dropped us as a straggler) or a new
CLUSTER_START mid-RPC aborts the current cluster and returns to the
main loop; SIGTERM (``repro.lifecycle.GracefulStop``) finishes the
in-flight RPC, sends BYE, and exits cleanly.

Elastic recovery: with ``reconnect`` enabled, losing the server
connection (server crash, not SHUTDOWN) does not end the worker — it
re-dials the server port with backoff and re-handshakes with REJOIN
instead of REGISTER, because its model/jits are already built; the
REJOIN_ACK tells it the committed round the resumed run continues
from, and it reports READY immediately (no rebuild, no warmup).
Fault rules are filtered by the worker's *incarnation* (respawn count,
passed by the orchestrator), so one-shot chaos faults don't re-fire in
a kill/respawn loop. RPC retry backoff is capped
(``lifecycle.retry_sleeps``) and the total retry budget is validated
against the server's straggler deadline at config time.
"""
from __future__ import annotations

import threading
import time
from collections import deque
from typing import List, Optional, Sequence

import numpy as np

from repro import streams
from repro.lifecycle import Backoff, GracefulStop, retry_sleeps
from repro.rt import protocol as pr
from repro.rt.faults import FaultInjector, FaultRule, InjectedDisconnect
from repro.rt.protocol import MsgType
from repro.rt.qos import QoSMonitor
from repro.rt.transport import Channel, RpcTimeout, connect_with_retry


def build_shards(spec: dict):
    """(images, labels, per-device index arrays) rebuilt deterministically
    from the plan's data spec — identical on server and every worker."""
    from repro.data.synthetic import non_iid_split, synthetic_mnist
    xtr, ytr, _, _ = synthetic_mnist(spec["n_train"], spec["n_test"],
                                     seed=spec["data_seed"])
    shards = non_iid_split(
        ytr, n_devices=spec["n_devices"],
        classes_per_device=spec.get("classes_per_device", 3),
        samples_per_device=spec["samples_per_device"],
        seed=spec["data_seed"])
    return xtr, ytr, shards


def member_batch_indices(device_indices, members: Sequence[int], B: int,
                         seed: int, rnd: int, m: int, l: int
                         ) -> List[np.ndarray]:
    """Per-member sample picks for (round, cluster, epoch) — entry for
    entry the draws ``CPSLDataset.cluster_batch(members,
    seed=batch_seed(seed, rnd, m, l))`` makes: one fresh ``default_rng``
    per (m, l), members drawn in slot order (draws are prefix-stable, so
    every worker reproduces the full cluster's stream and slices its own
    row; the server reuses the same picks for the labels)."""
    rng = streams.batch_rng(seed, rnd, m, l)
    picks = []
    for d in members:
        idx = device_indices[d]
        picks.append(rng.choice(idx, B, replace=len(idx) < B))
    return picks


class _Aborted(Exception):
    """Current cluster abandoned (server moved on / shutdown / error)."""


class DeviceWorker:
    def __init__(self, cfg: dict):
        self.cfg = cfg
        self.gid = int(cfg["device"])
        self.incarnation = int(cfg.get("incarnation", 0))
        self.injector = FaultInjector(
            [r for r in (FaultRule.from_dict(d)
                         for d in cfg.get("faults", []))
             if r.active_in(self.incarnation)])
        self.stop = GracefulStop().install()
        self.pending = deque()
        self.qos = QoSMonitor(device=self.gid)
        self._round: Optional[int] = None
        self._hb_stop = threading.Event()
        self.ch: Optional[Channel] = None

    # -- setup -----------------------------------------------------------

    def _connect_and_plan(self) -> dict:
        cfg = self.cfg
        sock = connect_with_retry(cfg["host"], cfg["port"],
                                  cfg.get("connect_timeout_s", 20.0))
        self.ch = Channel(sock, self.injector, round_fn=lambda: self._round)
        self.ch.send(MsgType.REGISTER, {"device": self.gid})
        mtype, plan = self.ch.recv(timeout=cfg.get("plan_timeout_s", 120.0))
        if mtype != MsgType.PLAN:
            raise pr.BadFrame(f"expected PLAN, got {mtype.name}")
        return plan

    def _build(self, plan: dict):
        # heavyweight imports deferred to the spawned process
        import jax
        import jax.numpy as jnp
        from repro import optim
        from repro.core.splitting import make_split_model

        assert plan["model"] == "lenet", plan["model"]
        self.plan = plan
        self.L = int(plan["local_epochs"])
        self.B = int(plan["batch"])
        self.seed = int(plan["seed"])
        self.x, _, self.shards = build_shards(plan["data"])
        split = make_split_model(plan["model"], int(plan["v"]))
        dev_opt = optim.make(plan["optimizer"], plan["lr_device"],
                             momentum=plan["momentum"],
                             weight_decay=plan["weight_decay"])

        # the decomposed protocol-step kernels (see module docstring)
        self._fwd = jax.jit(split.device_apply)

        def _bwd(dp, dopt, step, b, g):
            _, vjp = jax.vjp(lambda q: split.device_apply(q, b)[0], dp)
            g_dev = vjp(g)[0]
            return dev_opt.step(g_dev, dopt, dp, step)

        self._bwd = jax.jit(_bwd)
        self._jnp, self._jax = jnp, jax

        if plan.get("warmup", True):
            p0 = split.init_device(streams.warmup_key())
            batch = {"image": jnp.zeros((self.B, 28, 28, 1), jnp.float32)}
            sm, _ = self._fwd(p0, batch)
            g0 = jnp.zeros(split.smashed_spec(self.B).shape, jnp.float32)
            jax.block_until_ready(
                self._bwd(p0, dev_opt.init(p0), np.int32(0), batch, g0))

    def _start_heartbeat(self):
        interval = self.cfg.get("heartbeat_s", 0.5)
        ch = self.ch     # bind THIS channel: after a reconnect the old
                         # thread dies on the closed socket instead of
                         # silently adopting the new one

        def hb():
            while not self._hb_stop.wait(interval):
                try:
                    ch.send(MsgType.HEARTBEAT,
                            {"device": self.gid, "t": time.monotonic()})
                except Exception:
                    return

        threading.Thread(target=hb, daemon=True).start()

    # -- RPC -------------------------------------------------------------

    def _rpc(self, send_type: MsgType, payload, match) -> dict:
        """Send and await the matching reply, resending with exponential
        backoff on timeout. Raises _Aborted when the server moved on
        (new CLUSTER_START / SHUTDOWN pushed to pending, or ERROR), and
        after exhausting retries."""
        cfg = self.cfg
        timeout = cfg.get("rpc_timeout_s", 5.0)
        retries = int(cfg.get("retries", 3))
        sleeps = retry_sleeps(retries, cfg.get("backoff_s", 0.25),
                              cap=cfg.get("backoff_max_s", 2.0))
        for attempt in range(retries + 1):
            if attempt:
                time.sleep(sleeps[attempt - 1])
            self.ch.send(send_type, dict(payload, attempt=attempt))
            deadline = time.monotonic() + timeout
            while True:
                left = deadline - time.monotonic()
                if left <= 0:
                    break
                try:
                    mtype, msg = self.ch.recv(timeout=left)
                except RpcTimeout:
                    break
                if mtype in (MsgType.CLUSTER_START, MsgType.SHUTDOWN):
                    self.pending.append((mtype, msg))
                    raise _Aborted("server moved on")
                if mtype == MsgType.ERROR:
                    raise _Aborted(msg.get("reason", "server error"))
                if match(mtype, msg):
                    return msg
                # stale reply from an earlier attempt/epoch: ignore
        raise _Aborted(f"no reply to {send_type.name} "
                       f"after {retries + 1} attempts")

    # -- cluster participation -------------------------------------------

    def _run_cluster(self, msg: dict):
        jnp = self._jnp
        rnd, m, k = int(msg["round"]), int(msg["m"]), int(msg["k"])
        members = [int(d) for d in msg["members"]]
        step0 = int(msg["step"])
        self._round = rnd
        dev, dopt = msg["dev"], msg["dev_opt"]

        for l in range(self.L):
            picks = member_batch_indices(self.shards, members, self.B,
                                         self.seed, rnd, m, l)
            batch = {"image": jnp.asarray(self.x[picks[k]])}
            self.injector.sleep_compute(rnd)
            t0 = time.monotonic()
            smashed, _ = self._fwd(dev, batch)
            smashed = np.asarray(smashed)
            self.qos.emit(rnd, "fwd", time.monotonic() - t0,
                          cluster=m, epoch=l, slot=k)
            t0 = time.monotonic()
            reply = self._rpc(
                MsgType.SMASHED,
                {"round": rnd, "m": m, "epoch": l, "k": k,
                 "device": self.gid, "smashed": smashed},
                lambda mt, ms, l=l: (mt == MsgType.GRAD
                                     and ms.get("round") == rnd
                                     and ms.get("m") == m
                                     and ms.get("epoch") == l))
            self.qos.emit(rnd, "grad_wait", time.monotonic() - t0,
                          cluster=m, epoch=l, slot=k,
                          bytes=smashed.nbytes)
            t0 = time.monotonic()
            dev, dopt = self._bwd(dev, dopt, np.int32(step0 + l),
                                  batch, jnp.asarray(reply["g"]))
            self._jax.block_until_ready(dev)
            self.qos.emit(rnd, "bwd", time.monotonic() - t0,
                          cluster=m, epoch=l, slot=k)

        t0 = time.monotonic()
        self._rpc(
            MsgType.AGG,
            {"round": rnd, "m": m, "k": k, "device": self.gid,
             "dev": self._jax.tree.map(np.asarray, dev),
             "dev_opt": self._jax.tree.map(np.asarray, dopt),
             "qos": self.qos.drain()},
            lambda mt, ms: (mt == MsgType.AGG_ACK
                            and ms.get("round") == rnd
                            and ms.get("m") == m))

    # -- main loop -------------------------------------------------------

    def _serve(self):
        """Dispatch loop on the current channel; returns on clean
        SHUTDOWN (or triggered stop), raises on connection loss."""
        while not self.stop:
            if self.pending:
                mtype, msg = self.pending.popleft()
            else:
                try:
                    mtype, msg = self.ch.recv(timeout=0.5)
                except RpcTimeout:
                    continue
            if mtype == MsgType.SHUTDOWN:
                self.ch.send(MsgType.BYE, {"device": self.gid})
                return
            if mtype == MsgType.CLUSTER_START:
                try:
                    self._run_cluster(msg)
                except _Aborted:
                    self.qos.drain()   # cluster abandoned: QoS stale
            # anything else (stale GRAD/ACK/ERROR) is ignored here

    def _rejoin(self) -> bool:
        """Re-dial the server after losing it and re-handshake with
        REJOIN (model/jits already built, so no PLAN rebuild and no
        warmup — READY follows immediately). The WHOLE handshake is
        retried with capped backoff until ``reconnect_timeout_s``
        elapses, not just the TCP connect: racing a dying server's
        socket teardown can land a connect in a dead listener's backlog
        (accepted, then RST on first read), and a restarted server may
        be mid-bind — both are transient. Returns False only when the
        budget is exhausted — the worker then exits like before."""
        cfg = self.cfg
        self.pending.clear()
        self._round = None
        self.qos.drain()               # pre-crash QoS is unmatchable now
        deadline = time.monotonic() + cfg.get("reconnect_timeout_s", 30.0)
        backoff = Backoff(cfg.get("backoff_s", 0.25),
                          cap=cfg.get("backoff_max_s", 2.0))
        while not self.stop:
            left = deadline - time.monotonic()
            if left <= 0:
                return False
            if self._rejoin_once(left):
                return True
            time.sleep(min(backoff.next(),
                           max(0.0, deadline - time.monotonic())))
        return False

    def _rejoin_once(self, budget_s: float) -> bool:
        """One rejoin attempt: connect, REJOIN, await REJOIN_ACK (or a
        PLAN — server wants a full rebuild), READY. Any transport or
        protocol failure just fails this attempt."""
        cfg = self.cfg
        try:
            sock = connect_with_retry(cfg["host"], cfg["port"], budget_s)
            self.ch = Channel(sock, self.injector,
                              round_fn=lambda: self._round)
            self.ch.send(MsgType.REJOIN, {"device": self.gid,
                                          "incarnation": self.incarnation})
            mtype, msg = self.ch.recv(
                timeout=min(budget_s, cfg.get("plan_timeout_s", 120.0)))
            if mtype == MsgType.PLAN:
                self._build(msg)       # server asked for a full rebuild
            elif mtype != MsgType.REJOIN_ACK:
                return False
            self.ch.send(MsgType.READY, {"device": self.gid})
        except (pr.ProtocolError, RpcTimeout, OSError):
            return False
        self._start_heartbeat()
        return True

    def run(self):
        plan = self._connect_and_plan()
        self._build(plan)
        self.ch.send(MsgType.READY, {"device": self.gid})
        self._start_heartbeat()
        try:
            while True:
                try:
                    self._serve()
                    return             # SHUTDOWN / stop: clean exit
                except (pr.ConnectionClosed, pr.TruncatedFrame,
                        InjectedDisconnect, OSError):
                    if not self.cfg.get("reconnect", False) or self.stop:
                        return
                    if not self._rejoin():
                        return
        finally:
            self._hb_stop.set()
            try:
                self.ch.close()
            except Exception:
                pass


def device_main(cfg: dict):
    """Spawn entrypoint (top-level so multiprocessing can pickle it)."""
    DeviceWorker(cfg).run()
