"""Framed socket transport: one ``Channel`` per TCP connection.

Whole frames are written under a lock, so a device's heartbeat thread
can interleave with its main loop without corrupting the stream (TCP
preserves order; receivers always see complete frames). Receives are
single-consumer by construction: the server runs one reader thread per
connection, devices receive only from their main loop.

Timeout semantics: ``recv(timeout)`` bounds the wait for the *start* of
a frame (``RpcTimeout``); once a header has arrived the body is given a
generous fixed budget, because sends are atomic whole frames — a stall
mid-frame means the peer died mid-write (``TruncatedFrame``), not that
it is merely slow. EOF between frames is ``ConnectionClosed``.

Fault hooks: an attached ``FaultInjector`` is consulted on every send —
'delay' sleeps first, 'drop' swallows the frame (the caller believes it
sent, exercising retry), 'disconnect' hard-closes the socket and raises
``InjectedDisconnect``.
"""
from __future__ import annotations

import os
import signal
import socket
import threading
import time
from typing import Any, Callable, Optional, Tuple

from repro.rt import protocol as pr
from repro.rt.faults import FaultInjector, InjectedDisconnect

_BODY_TIMEOUT = 60.0      # mid-frame stall budget (peer died mid-write)


class RpcTimeout(RuntimeError):
    pass


def _read_exact(sock: socket.socket, n: int, timeout: Optional[float],
                mid_frame: bool) -> bytes:
    """Read exactly n bytes; socket timeouts become RpcTimeout (frame
    start) or TruncatedFrame (mid-frame); EOF likewise."""
    buf = b""
    deadline = None if timeout is None else time.monotonic() + timeout
    while len(buf) < n:
        if deadline is not None:
            left = deadline - time.monotonic()
            if left <= 0:
                if mid_frame or buf:
                    raise pr.TruncatedFrame(
                        f"stalled with {len(buf)} of {n} bytes")
                raise RpcTimeout("no frame within timeout")
            sock.settimeout(left)
        else:
            sock.settimeout(None)
        try:
            chunk = sock.recv(n - len(buf))
        except socket.timeout:
            if mid_frame or buf:
                raise pr.TruncatedFrame(
                    f"stalled with {len(buf)} of {n} bytes") from None
            raise RpcTimeout("no frame within timeout") from None
        if not chunk:
            if mid_frame or buf:
                raise pr.TruncatedFrame(
                    f"EOF with {len(buf)} of {n} bytes")
            raise pr.ConnectionClosed("peer closed the connection")
        buf += chunk
    return buf


class Channel:
    def __init__(self, sock: socket.socket,
                 injector: Optional[FaultInjector] = None,
                 round_fn: Optional[Callable[[], Optional[int]]] = None):
        sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        self.sock = sock
        self.injector = injector
        self.round_fn = round_fn or (lambda: None)
        self._send_lock = threading.Lock()
        # senders (main + heartbeat thread) race close(): both the flag
        # and the socket writes serialize on _send_lock
        self._closed = False       # guarded-by: _send_lock

    # -- send ------------------------------------------------------------

    def send(self, mtype: pr.MsgType, payload: Any) -> bool:
        """Send one frame. Returns False when a 'drop' fault swallowed
        it; raises InjectedDisconnect on a 'disconnect' fault."""
        buf = pr.frame(mtype, payload)
        if self.injector is not None:
            act = self.injector.on_send(mtype, self.round_fn())
            if act is not None:
                kind, delay = act
                if kind == "drop":
                    return False
                if kind == "kill":
                    # a real crash, not an exception: no BYE, no socket
                    # shutdown, no atexit — exactly what SIGKILL does
                    os.kill(os.getpid(), signal.SIGKILL)
                if kind == "disconnect":
                    self.close()
                    raise InjectedDisconnect(
                        f"injected disconnect on {mtype.name}")
                if kind == "delay" and delay > 0:
                    time.sleep(delay)
        with self._send_lock:
            if self._closed:
                raise pr.ConnectionClosed("channel already closed")
            self.sock.sendall(buf)
        return True

    # -- recv ------------------------------------------------------------

    def recv(self, timeout: Optional[float] = None
             ) -> Tuple[pr.MsgType, Any]:
        hdr = _read_exact(self.sock, pr.HEADER.size, timeout,
                          mid_frame=False)
        mtype, length = pr.parse_header(hdr)
        body = _read_exact(self.sock, length, _BODY_TIMEOUT,
                           mid_frame=True) if length else b""
        return mtype, pr.decode_payload(body)

    def close(self):
        with self._send_lock:
            if self._closed:
                return
            self._closed = True
            try:
                self.sock.shutdown(socket.SHUT_RDWR)
            except OSError:
                pass
            self.sock.close()


def connect_with_retry(host: str, port: int, total_timeout: float = 20.0,
                       backoff0: float = 0.1) -> socket.socket:
    """Dial with exponential backoff until the listener is up (workers
    race the orchestrator's bind at spawn time)."""
    deadline = time.monotonic() + total_timeout
    backoff = backoff0
    while True:
        try:
            return socket.create_connection((host, port), timeout=5.0)
        except OSError:
            if time.monotonic() + backoff > deadline:
                raise
            time.sleep(backoff)
            backoff = min(backoff * 2, 2.0)
