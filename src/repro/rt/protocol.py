"""Wire format for the CPSL deployment runtime.

Frames are length-prefixed msgpack:

    +-------+---------+----------+-----+-------------------+
    | magic | version | msg type | pad | body length (u32) |  8-byte header
    +-------+---------+----------+-----+-------------------+
    |                msgpack-encoded payload               |
    +------------------------------------------------------+

The payload codec round-trips the pytrees the CPSL protocol actually
ships (device/optimizer params, smashed activations, cut-layer
gradients) exactly:

  * numpy / jax arrays -> ``{"__nd__": {dtype-name, shape, raw bytes}}``
    — dtype by *name* so extension dtypes (bfloat16 via ml_dtypes)
    survive; 0-d arrays keep shape ``[]``. Anything exposing
    ``__array__`` (jax device arrays, np scalars) is materialized to
    host numpy first, so callers never pre-convert.
  * tuples -> ``{"__tuple__": [...]}`` — msgpack would silently decode
    them as lists, but optimizer states are tuples (sgd's is the empty
    tuple) and pytree *structure* must survive the wire for the
    bit-exactness contract.

Bit-exactness note: arrays cross the wire as raw ``tobytes`` and come
back via ``frombuffer`` — the identity roundtrip the loopback
equivalence test relies on (no float re-encoding anywhere).

Errors: ``BadMagic`` (the peer is not speaking this protocol at all),
``VersionMismatch`` (right protocol, wrong revision — carries
``peer_version``/``our_version`` and names both in the message so a
mixed-version deployment is diagnosable from the exception alone),
``BadFrame`` (unknown message type / malformed payload),
``TruncatedFrame`` (EOF or stall mid-frame), ``ConnectionClosed``
(clean EOF between frames). All derive from ``ProtocolError``.
"""
from __future__ import annotations

import enum
import struct
from typing import Any, Tuple

import msgpack
import numpy as np

MAGIC = 0xC5
VERSION = 1
HEADER = struct.Struct(">BBBxI")   # magic, version, msg type, pad, length
MAX_FRAME = 1 << 30                # sanity bound: 1 GiB


class MsgType(enum.IntEnum):
    REGISTER = 1       # device -> server: {device}
    PLAN = 2           # server -> device: static run parameters
    CLUSTER_START = 3  # server -> device: {round, m, k, members, dev,
                       #                    dev_opt, step}
    SMASHED = 4        # device -> server: {round, m, epoch, k, smashed}
    GRAD = 5           # server -> device: {round, m, epoch, g}
    AGG = 6            # device -> server: {round, m, k, dev, dev_opt, qos}
    AGG_ACK = 7        # server -> device: {round, m}
    HEARTBEAT = 8      # device -> server: {device, t}
    SHUTDOWN = 9       # server -> device: {}
    BYE = 10           # device -> server: {device}
    ERROR = 11         # server -> device: {reason} (e.g. dropped straggler)
    READY = 12         # device -> server: warmup/jit done, {device}
    REJOIN = 13        # device -> server: already-built worker reconnecting
                       #                   after a server restart, {device}
    REJOIN_ACK = 14    # server -> device: {round, step} — the committed
                       #                   round/step counters the resumed
                       #                   run will continue from (device
                       #                   params ride CLUSTER_START as
                       #                   always: workers are stateless
                       #                   between clusters by design)


class ProtocolError(RuntimeError):
    pass


class VersionMismatch(ProtocolError):
    """The peer frames this protocol but at a different revision.

    Actionable by construction: ``peer_version`` / ``our_version`` are
    carried as attributes and both are named in the message, so a
    mixed-version deployment (e.g. an old worker rejoining an upgraded
    server) fails with "upgrade X" instead of a generic frame error.
    """

    def __init__(self, peer_version: int, our_version: int):
        self.peer_version = int(peer_version)
        self.our_version = int(our_version)
        newer = self.peer_version > self.our_version
        super().__init__(
            f"protocol version mismatch: peer speaks v{peer_version}, "
            f"we speak v{our_version} — upgrade "
            f"{'this side' if newer else 'the peer'} so both ends run "
            f"the same repro.rt revision")


class BadMagic(VersionMismatch):
    """Wrong magic byte: the peer is not speaking this protocol at all
    (or the stream desynchronized). Subclasses ``VersionMismatch`` so
    existing handlers keep catching it."""

    def __init__(self, magic: int):
        self.magic = int(magic)
        ProtocolError.__init__(
            self, f"bad magic 0x{magic:02x} (expected 0x{MAGIC:02x}): "
                  f"peer is not a repro.rt endpoint")


class BadFrame(ProtocolError):
    pass


class TruncatedFrame(ProtocolError):
    pass


class ConnectionClosed(ProtocolError):
    pass


# -- payload codec -----------------------------------------------------------

def _enc(o: Any) -> Any:
    if isinstance(o, np.ndarray):
        shape = list(o.shape)          # before ascontiguousarray: it
        a = np.ascontiguousarray(o)    # promotes 0-d -> (1,)
        return {"__nd__": {"dtype": a.dtype.name, "shape": shape,
                           "data": a.tobytes()}}
    if isinstance(o, np.generic):      # numpy scalar: keep its dtype
        return _enc(np.asarray(o))
    if isinstance(o, tuple):
        return {"__tuple__": [_enc(x) for x in o]}
    if isinstance(o, list):
        return [_enc(x) for x in o]
    if isinstance(o, dict):
        return {k: _enc(v) for k, v in o.items()}
    if hasattr(o, "__array__") and not isinstance(o, (str, bytes)):
        return _enc(np.asarray(o))     # jax device arrays etc.
    return o


def _np_dtype(name: str) -> np.dtype:
    try:
        return np.dtype(name)
    except TypeError:
        # extension dtypes are registered by ml_dtypes (a jax dep)
        import ml_dtypes                      # noqa: F401
        return np.dtype(getattr(ml_dtypes, name))


def _dec(o: Any) -> Any:
    if isinstance(o, dict):
        if "__nd__" in o and len(o) == 1:
            d = o["__nd__"]
            arr = np.frombuffer(d["data"], dtype=_np_dtype(d["dtype"]))
            return arr.reshape(d["shape"])
        if "__tuple__" in o and len(o) == 1:
            return tuple(_dec(x) for x in o["__tuple__"])
        return {k: _dec(v) for k, v in o.items()}
    if isinstance(o, list):
        return [_dec(x) for x in o]
    return o


def encode_payload(obj: Any) -> bytes:
    return msgpack.packb(_enc(obj), use_bin_type=True)


def decode_payload(raw: bytes) -> Any:
    try:
        obj = msgpack.unpackb(raw, raw=False, strict_map_key=False)
    except Exception as e:              # malformed msgpack
        raise BadFrame(f"undecodable payload: {e}") from e
    return _dec(obj)


# -- framing -----------------------------------------------------------------

def frame(mtype: MsgType, payload: Any) -> bytes:
    body = encode_payload(payload)
    return HEADER.pack(MAGIC, VERSION, int(mtype), len(body)) + body


def parse_header(hdr: bytes) -> Tuple[MsgType, int]:
    if len(hdr) != HEADER.size:
        raise TruncatedFrame(f"short header: {len(hdr)} bytes")
    magic, version, mtype, length = HEADER.unpack(hdr)
    if magic != MAGIC:
        raise BadMagic(magic)
    if version != VERSION:
        raise VersionMismatch(peer_version=version, our_version=VERSION)
    if length > MAX_FRAME:
        raise BadFrame(f"frame of {length} bytes exceeds cap {MAX_FRAME}")
    try:
        return MsgType(mtype), length
    except ValueError as e:
        raise BadFrame(f"unknown message type {mtype}") from e


def unpack_frame(buf: bytes) -> Tuple[MsgType, Any]:
    """Parse one complete frame from a byte string (tests / in-memory
    transports; sockets use ``transport.Channel`` which reads the header
    and body incrementally)."""
    mtype, length = parse_header(buf[:HEADER.size])
    body = buf[HEADER.size:]
    if len(body) < length:
        raise TruncatedFrame(f"body has {len(body)} of {length} bytes")
    return mtype, decode_payload(body[:length])
