"""repro.rt — multi-process CPSL deployment runtime.

Everything else in the repo *simulates* CPSL's wireless schedule; this
package *executes* it: N device worker processes and one server process
run real CPSL rounds over localhost sockets — devices run
``SplitModel.device_apply`` forward and ship serialized smashed
activations, the server runs ``server_loss``/backward and returns
cut-layer gradients, and the orchestrator drives the paper's
cluster-parallel-then-sequential schedule from a ``Plan`` produced by
the ``sim.controller`` two-timescale planner.

Modules:
  protocol      length-prefixed msgpack wire format, versioned msg types
  transport     framed Channel: timeouts, retry/backoff, fault hooks
  faults        deterministic delay/drop/disconnect/slow injection
  qos           measured per-device phase timings (telemetry schema)
  device        the device worker process (``device_main``)
  server        server-side numerics + straggler drop-or-wait policy
  orchestrator  spawn/plan/drive/collect (``run_loopback``), elastic
                recovery (``run_elastic``: WAL crash-resume, worker
                respawn/rejoin, roster-aware replanning)
  crossval      measured vs sim-predicted round latency, side by side

Correctness contract: a loopback run with 2 clusters x 2 devices
reproduces the in-process looped ``CPSL.run_round`` bit-exactly (same
rng streams, same batch index tables) — tests/test_rt_loopback.py.
Recovery contract: a chaos run (seeded worker SIGKILLs + server
SIGKILLs, ``faults.chaos_schedule``) that recovers losslessly converges
to the SAME final params bit-exactly — tests/test_rt_recovery.py.
"""
from repro.rt.faults import (ChaosPlan, FaultInjector, FaultRule,
                             chaos_schedule, wireless_delay_rules)
from repro.rt.orchestrator import (Orchestrator, RTConfig,
                                   loopback_reference, run_elastic,
                                   run_loopback)
from repro.rt.protocol import MsgType, ProtocolError

__all__ = ["FaultInjector", "FaultRule", "wireless_delay_rules",
           "ChaosPlan", "chaos_schedule",
           "Orchestrator", "RTConfig", "run_loopback", "run_elastic",
           "loopback_reference", "MsgType", "ProtocolError"]
