"""Sim-vs-runtime cross-validation: predicted vs measured round latency.

A loopback trace carries, per executed round, both the eq. 15-25 cost
model's prediction for the executed plan (``latency_s`` /
``planned_latency_s``, re-derivable from the recorded ``v / clusters /
xs / f / rate`` snapshot via ``sim.engine.recompute_trace_latencies``)
and the measured wall-clock (``wall_s``). This module joins the two per
round — the fidelity check the paper's simulation results implicitly
assume: does the deployed runtime's timing track the analytical model?

On plain loopback the measured times are dominated by real compute +
localhost I/O, so the interesting column is the *ratio's stability*
across rounds; with ``RTConfig.delay_scale`` the priced wireless delays
are physically injected and measured/predicted converge toward the
scale factor (benchmarks/bench_rt.py exercises that regime).
"""
from __future__ import annotations

import json
from typing import List, Optional

import numpy as np


def crossval_rows(records, prof=None, ncfg=None, B: Optional[int] = None,
                  L: Optional[int] = None) -> List[dict]:
    """Per-round {round, predicted_s, measured_s, ratio} rows from a
    trace. Predictions prefer a fresh reprice of the recorded snapshot
    (when ``prof``/``ncfg``/``B``/``L`` are given) over the recorded
    ``latency_s`` / ``planned_latency_s``."""
    from repro.rt.qos import round_wall_clocks

    measured = round_wall_clocks(records)
    predicted = {}
    # rounds recompute_trace_latencies would price, in its order
    priceable = [rec for rec in records
                 if not rec.get("skipped") and "v" in rec]
    for rec in priceable:
        lat = rec.get("latency_s", rec.get("planned_latency_s"))
        if lat is not None:
            predicted[int(rec["round"])] = float(lat)
    if prof is not None and ncfg is not None:
        from repro.sim.engine import recompute_trace_latencies
        lats = recompute_trace_latencies(records, prof, ncfg, B, L)
        for rec, lat in zip(priceable, lats):
            predicted[int(rec["round"])] = float(lat)

    rows = []
    for rnd in sorted(set(measured) & set(predicted)):
        p, m = predicted[rnd], measured[rnd]
        rows.append({"round": rnd, "predicted_s": p, "measured_s": m,
                     "ratio": (m / p if p > 0 else float("inf"))})
    return rows


def summarize(rows: List[dict]) -> dict:
    """Aggregate fidelity stats over the joined rounds."""
    if not rows:
        return {"n_rounds": 0}
    ratios = np.array([r["ratio"] for r in rows], np.float64)
    return {"n_rounds": len(rows),
            "predicted_total_s": float(sum(r["predicted_s"] for r in rows)),
            "measured_total_s": float(sum(r["measured_s"] for r in rows)),
            "ratio_mean": float(ratios.mean()),
            "ratio_min": float(ratios.min()),
            "ratio_max": float(ratios.max()),
            # relative spread of the per-round ratio: how *stable* the
            # model's (scaled) prediction is across rounds
            "ratio_rel_spread": float(
                (ratios.max() - ratios.min()) / max(ratios.mean(), 1e-12))}


def crossval_report(records, prof=None, ncfg=None,
                    B: Optional[int] = None, L: Optional[int] = None,
                    path: Optional[str] = None) -> dict:
    """{rows, summary}; optionally written to ``path`` as JSON (the CI
    loopback smoke job uploads this artifact)."""
    rows = crossval_rows(records, prof, ncfg, B, L)
    report = {"rows": rows, "summary": summarize(rows)}
    if path:
        with open(path, "w") as f:
            json.dump(report, f, indent=2)
    return report
