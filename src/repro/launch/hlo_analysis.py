"""Post-SPMD HLO analysis for the roofline.

XLA's ``compiled.cost_analysis()`` counts while-loop bodies ONCE, but our
layer stacks compile to while loops (lax.scan), so we parse
``compiled.as_text()`` ourselves and propagate loop trip counts:

  - collective bytes: all-gather / all-reduce(x2: reduce+broadcast phases)
    / reduce-scatter / all-to-all / collective-permute result bytes,
  - dot FLOPs: 2 * prod(result dims) * prod(lhs contracting dims),
  - HBM traffic proxy: operand+result bytes of top-level (fusion-boundary)
    instructions — fusion boundaries are where tensors round-trip HBM.

Trip counts come from each while condition's compare(_, constant(N));
call-graph edges: while bodies (xN), calls/conditionals (x1). Instructions
inside fusion bodies are not double-counted for memory.

All numbers are PER-DEVICE (the HLO is the partitioned per-device module).
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict, List, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(?P<rtype>.+?)\s"
    r"(?P<op>[\w\-]+)\((?P<args>.*)$")
# computation headers sit at column 0: `%name (params...) -> type {`
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%([\w.\-]+)\s*\(.*\)\s*->\s*.+\{\s*$")


def _dims(dim_str: str) -> List[int]:
    return [int(d) for d in dim_str.split(",")] if dim_str else []


def _shape_bytes(type_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt = m.group(1)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in _dims(m.group(2)):
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


def _split_computations(hlo: str) -> Dict[str, List[str]]:
    comps: Dict[str, List[str]] = {}
    cur = None
    for line in hlo.splitlines():
        if cur is None:
            m = _COMP_RE.match(line)
            if m:
                cur = m.group(1)
                comps[cur] = []
            continue
        if line.rstrip() == "}":
            cur = None
        else:
            comps[cur].append(line)
    return comps


def _trip_count(cond_lines: List[str]) -> int:
    consts = {}
    for ln in cond_lines:
        m = re.match(r"\s*%?([\w.\-]+)\s*=\s*\w+\[\]\s*constant\((\d+)\)", ln)
        if m:
            consts[m.group(1)] = int(m.group(2))
    for ln in cond_lines:
        if "compare(" in ln:
            args = re.search(r"compare\(([^)]*)\)", ln)
            if not args:
                continue
            for a in args.group(1).split(","):
                name = a.strip().split(" ")[-1].lstrip("%")
                if name in consts:
                    return consts[name]
    # compare is often wrapped in a fusion: the loop bound is the scalar
    # constant in the condition computation (there is exactly one).
    if consts:
        return max(consts.values())
    return 1


_NAME_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=")


def _operand_names(args: str) -> List[str]:
    """Operand instruction names from the args portion (up to the closing
    paren of the operand list)."""
    depth = 1
    out = []
    cur = ""
    for ch in args:
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                break
        cur += ch
    for tok in cur.split(","):
        tok = tok.strip()
        m = re.search(r"%([\w.\-]+)\s*$", tok)
        if m:
            out.append(m.group(1))
    return out


def _dot_flops(rtype: str, args: str, line: str, symtab: Dict[str, str]
               ) -> float:
    rm = _SHAPE_RE.search(rtype)
    if not rm:
        return 0.0
    n = 1
    for d in _dims(rm.group(2)):
        n *= d
    ops = _operand_names(args)
    lhs_dims: List[int] = []
    if ops and ops[0] in symtab:
        lm = _SHAPE_RE.search(symtab[ops[0]])
        if lm:
            lhs_dims = _dims(lm.group(2))
    cm = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", line)
    k = 1
    if cm and cm.group(1):
        for ci in _dims(cm.group(1)):
            if ci < len(lhs_dims):
                k *= lhs_dims[ci]
    return 2.0 * n * k


def _conv_flops(rtype: str, args: str, line: str, symtab: Dict[str, str]
                ) -> float:
    rm = _SHAPE_RE.search(rtype)
    if not rm:
        return 0.0
    n = 1
    for d in _dims(rm.group(2)):
        n *= d
    ops = _operand_names(args)
    kdims: List[int] = []
    if len(ops) > 1 and ops[1] in symtab:
        km = _SHAPE_RE.search(symtab[ops[1]])
        if km:
            kdims = _dims(km.group(2))
    kprod = 1
    for d in kdims:
        kprod *= d
    dm = re.search(r"dim_labels=\S*_(\S*?)->", line)
    out_feat = max(kdims) if kdims else 1
    if dm:
        lbl = dm.group(1)
        if "o" in lbl and lbl.index("o") < len(kdims):
            out_feat = kdims[lbl.index("o")]
    return 2.0 * n * kprod / max(out_feat, 1)


class HLOStats:
    def __init__(self):
        self.flops = 0.0
        self.hbm_bytes = 0.0
        self.coll = defaultdict(float)

    @property
    def collective_bytes(self):
        return sum(self.coll.values())


def analyze(hlo: str) -> HLOStats:
    comps = _split_computations(hlo)
    em = re.search(r"ENTRY\s+%?([\w.\-]+)", hlo)
    entry = em.group(1) if em else next(iter(comps))

    # per-computation locals
    loc_flops: Dict[str, float] = defaultdict(float)
    loc_bytes: Dict[str, float] = defaultdict(float)
    loc_coll: Dict[str, Dict[str, float]] = defaultdict(
        lambda: defaultdict(float))
    edges: Dict[str, List[Tuple[str, float, str]]] = defaultdict(list)

    # HBM-traffic ops: fusion boundaries + data movement. Standalone
    # elementwise ops (convert/add/exp/...) are EXCLUDED — on the TPU
    # target they fuse into neighbors; the CPU backend leaves them
    # unfused, which would wildly over-count the target's HBM traffic.
    _MEM_OPS = {"fusion", "dot", "convolution", "copy", "concatenate",
                "dynamic-update-slice", "dynamic-slice", "slice",
                "scatter", "gather", "sort", "pad", "reduce",
                "reduce-window", "select-and-scatter", "transpose",
                "custom-call", "cholesky", "triangular-solve"}

    for name, lines in comps.items():
        # symbol table: instruction name -> result type string
        symtab: Dict[str, str] = {}
        for ln in lines:
            nm = _NAME_RE.match(ln)
            im = _INSTR_RE.match(ln)
            if nm and im:
                symtab[nm.group(1)] = im.group("rtype")

        def op_bytes(args):
            return sum(_shape_bytes(symtab.get(o, ""))
                       for o in _operand_names(args))

        for ln in lines:
            im = _INSTR_RE.match(ln)
            if not im:
                continue
            op = im.group("op")
            rtype = im.group("rtype")
            args = im.group("args")
            if op == "while":
                bm = re.search(r"body=%?([\w.\-]+)", ln)
                cm = re.search(r"condition=%?([\w.\-]+)", ln)
                if bm and cm:
                    trips = _trip_count(comps.get(cm.group(1), []))
                    edges[name].append((bm.group(1), float(trips), "while"))
                continue
            base = op.replace("-start", "")
            if base in COLLECTIVES:
                b = _shape_bytes(rtype)
                if base == "all-reduce":
                    b *= 2
                loc_coll[name][base] += b
                loc_bytes[name] += _shape_bytes(rtype) + op_bytes(args)
                continue
            if op in ("fusion",):
                fm = re.search(r"calls=%?([\w.\-]+)", ln)
                if fm and fm.group(1) in comps:
                    edges[name].append((fm.group(1), 1.0, "fusion"))
            if op in ("call", "conditional"):
                for cm2 in re.finditer(r"(?:to_apply=|calls=|branch_computations=\{)"
                                       r"%?([\w.\-]+)", ln):
                    if cm2.group(1) in comps:
                        edges[name].append((cm2.group(1), 1.0, "call"))
            if op == "dot":
                loc_flops[name] += _dot_flops(rtype, args, ln, symtab)
            elif op == "convolution":
                loc_flops[name] += _conv_flops(rtype, args, ln, symtab)
            if op in _MEM_OPS:
                loc_bytes[name] += _shape_bytes(rtype) + op_bytes(args)

    stats = HLOStats()
    stack = []

    def visit(comp: str, mult: float, via_fusion: bool):
        if comp in stack:
            return
        stack.append(comp)
        stats.flops += loc_flops.get(comp, 0.0) * mult
        if not via_fusion:
            stats.hbm_bytes += loc_bytes.get(comp, 0.0) * mult
        for kind, b in loc_coll.get(comp, {}).items():
            stats.coll[kind] += b * mult
        for callee, m, ek in edges.get(comp, []):
            visit(callee, mult * m, via_fusion or ek == "fusion")
        stack.pop()

    visit(entry, 1.0, False)
    return stats


def report(hlo: str) -> dict:
    s = analyze(hlo)
    return {
        "parsed_flops_per_device": s.flops,
        "parsed_hbm_bytes_per_device": s.hbm_bytes,
        "collective_bytes_per_device": s.collective_bytes,
        "collectives_by_kind": {k: int(v) for k, v in s.coll.items()},
    }
