import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ REQUIRED first lines: jax locks the device count at first init. The
# dry-run (and only the dry-run) builds the 256/512-chip production mesh
# out of host placeholder devices. Tests/benches must see 1 device.
if os.environ.get("REPRO_DRYRUN_DEVICES"):
    os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count="
                               + os.environ["REPRO_DRYRUN_DEVICES"])

"""Multi-pod dry-run (deliverable e).

For every (architecture x input-shape) cell, build the production mesh,
jit the corresponding step with explicit in/out shardings,
``.lower().compile()`` it, and record:
  - memory_analysis()  (per-device bytes: proves it fits),
  - cost_analysis()    (XLA's own numbers, loop bodies counted once),
  - the loop-aware HLO parse (FLOPs / HBM bytes / collective bytes),
  - the three roofline terms + MODEL_FLOPS ratio (deliverable g).

Usage:
    python -m repro.launch.dryrun --arch qwen3-32b --cell train_4k \
        --mesh pod1 --out experiments/dryrun
    python -m repro.launch.dryrun --all --mesh pod2
Variants (perf iterations) override config fields:
    --override remat=False --override attn_impl=naive --tag noremat
"""
import argparse
import dataclasses
import json
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import streams
from repro import optim
from repro.configs import registry
from repro.configs.base import CPSLConfig, SHAPES, ModelConfig, ShapeCfg
from repro.core import partitioning as pt
from repro.core.cpsl import CPSL
from repro.core.splitting import make_split_model
from repro.launch import hlo_analysis
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import roofline_terms
from repro.models import api
from repro.models import transformer as tfm
from repro.models import whisper as whp

# realistic default cut layers (shallow per the paper's POOL1 finding;
# below the first MoE block where one exists so expert banks stay
# server-side — see DESIGN.md §Arch-applicability)
DEFAULT_CUTS = {
    "deepseek-v2-lite-16b": 1, "phi3.5-moe-42b-a6.6b": 1,
    "jamba-v0.1-52b": 1, "whisper-small": 2,
}

# grad-accumulation splits. MEASURED NOTE (EXPERIMENTS.md §Perf): with the
# fsdp profile at global_batch 256 == chip count, m=2 drops the per-step
# batch BELOW the chip count, the 'model' axis falls out of the batch
# sharding, and activations replicate 16x (compute term x15). Microbatching
# only helps when batch > chips; all cells here default to 1.
DEFAULT_MICROBATCHES = {}


def default_cut(cfg: ModelConfig) -> int:
    return DEFAULT_CUTS.get(cfg.name, 2)


def best_remat_group(n_periods: int) -> int:
    """Divisor of n_periods nearest sqrt(n_periods) (sqrt-remat)."""
    import math as _m
    best, target = 1, _m.sqrt(max(n_periods, 1))
    for d in range(1, n_periods + 1):
        if n_periods % d == 0 and abs(d - target) < abs(best - target):
            best = d
    return best


# --------------------------------------------------------------------------
# sharding builders
# --------------------------------------------------------------------------

def _client_axes(mesh, K=None):
    """Mesh axes for the stacked client dim, per the ACTIVE profile rules
    (fit to K when given)."""
    r = pt._resolve("clients")
    if r is None:
        return ()
    axes = r if isinstance(r, tuple) else (r,)
    if K is not None:
        fitted = pt._fit(tuple(axes), K)
        if fitted is None:
            return ()
        axes = fitted if isinstance(fitted, tuple) else (fitted,)
    return tuple(axes)


def dev_shardings(tree, mesh):
    """Stacked-client param trees: leading K axis per the profile's
    'clients' rule, inner dims by the param rules minus the client axes."""
    inner = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape[1:], s.dtype), tree)
    specs = pt.param_specs(inner)

    def mk(leaf_spec, leaf):
        K = leaf.shape[0]
        ca = _client_axes(mesh, K)

        def strip(ax):
            if ax is None:
                return None
            parts = ax if isinstance(ax, tuple) else (ax,)
            rest = tuple(a for a in parts if a not in ca)
            if not rest:
                return None
            return rest if len(rest) > 1 else rest[0]

        return NamedSharding(mesh, P(ca if ca else None,
                                     *[strip(a) for a in leaf_spec]))

    return jax.tree.map(mk, specs, tree,
                        is_leaf=lambda x: isinstance(x, P))


def srv_shardings(tree, mesh):
    specs = pt.param_specs(tree)
    return jax.tree.map(lambda sp: NamedSharding(mesh, sp), specs,
                        is_leaf=lambda x: isinstance(x, P))


def state_shardings(state_shapes, mesh):
    out = {}
    for key, sub in state_shapes.items():
        if key in ("dev", "dev_opt", "ef"):
            out[key] = dev_shardings(sub, mesh)
        elif key in ("srv", "srv_opt"):
            out[key] = srv_shardings(sub, mesh)
        else:
            out[key] = jax.tree.map(
                lambda _: NamedSharding(mesh, P()), sub)
    return out


def batch_shardings(batch_shapes, mesh, leading_clients=True):
    """(K, B, ...) batches: K per the clients rule; B picks up whatever
    batch-rule axes remain (fsdp: B shards over 'model')."""
    def mk(s):
        K = s.shape[0]
        ca = _client_axes(mesh, K)
        r = pt._resolve("batch")
        all_ax = (r if isinstance(r, tuple) else (r,)) if r else ()
        leftover = tuple(a for a in all_ax if a not in ca)
        b_ax = None
        if leading_clients and len(s.shape) > 1 and leftover:
            b_ax = pt._fit(leftover, s.shape[1])
        rest = (None,) * max(0, len(s.shape) - 2)
        return NamedSharding(mesh, P(ca if ca else None, b_ax, *rest))

    return jax.tree.map(mk, batch_shapes)


def cache_shardings(cache_shapes, mesh, long_ctx: bool):
    all_ax = tuple(a for a in ("pod", "data", "model") if a in mesh.axis_names)
    flat = jax.tree_util.tree_flatten_with_path(cache_shapes)[0]
    specs = []
    for path, leaf in flat:
        keys = [str(getattr(p, "key", getattr(p, "idx", ""))) for p in path]
        stacked = "stack" in keys
        name = keys[-1]
        nd = leaf.ndim - (1 if stacked else 0)
        bdim = leaf.shape[1] if stacked else leaf.shape[0]
        if long_ctx:
            batch_ax, seq_ax = None, all_ax
        else:
            batch_ax = _client_axes(mesh, bdim) or None
            seq_ax = "model"
        if name in ("k", "v", "mk", "mv"):      # (B, S, G, hd)
            sp = (batch_ax, seq_ax, None, None)
        elif name in ("ckv", "kr"):             # (B, S, r)
            sp = (batch_ax, seq_ax, None)
        elif name == "conv":                    # (B, K-1, C)
            sp = (batch_ax, None, "model" if not long_ctx else None)
        elif name == "ssm":                     # (B, H, N, P)
            sp = (batch_ax, "model" if not long_ctx else "model", None, None)
        else:
            sp = (None,) * nd
        sp = sp[:nd] + (None,) * max(0, nd - len(sp))
        if stacked:
            sp = (None,) + sp
        specs.append(NamedSharding(mesh, P(*sp)))
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(cache_shapes), specs)


# --------------------------------------------------------------------------
# cell builders: return (jitted, arg_shapes)
# --------------------------------------------------------------------------

def build_train(cfg: ModelConfig, shape: ShapeCfg, mesh, cut: int,
                cluster_size: int, microbatches: int = 1, ccfg_over=None):
    K = cluster_size
    B = shape.global_batch // K
    assert B >= 1, (shape.global_batch, K)
    ccfg = CPSLConfig(cut_layer=cut, cluster_size=K, batch_per_device=B,
                      optimizer="adamw_mixed", lr_device=1e-4,
                      lr_server=1e-4,
                      microbatches=min(microbatches, B))
    if ccfg_over:
        kw = {}
        for ov in ccfg_over:
            k_, v_ = ov.split("=", 1)
            cur = getattr(ccfg, k_)
            if isinstance(cur, bool):
                v_ = v_ in ("1", "true", "True")
            elif isinstance(cur, int):
                v_ = int(v_)
            elif isinstance(cur, float):
                v_ = float(v_)
            kw[k_] = v_
        ccfg = dataclasses.replace(ccfg, **kw)
    split = make_split_model(cfg, cut)
    cpsl = CPSL(split, ccfg)
    state_shapes = jax.eval_shape(cpsl.init_state, streams.warmup_key())
    sds = jax.ShapeDtypeStruct
    batch_shapes = {"tokens": sds((K, B, shape.seq_len), jnp.int32),
                    "labels": sds((K, B, shape.seq_len), jnp.int32)}
    if cfg.encdec:
        batch_shapes["frames"] = sds((K, B, cfg.enc_seq, cfg.d_model),
                                     jnp.dtype(cfg.dtype))
        batch_shapes["tokens"] = sds((K, B, shape.seq_len), jnp.int32)
    st_sh = state_shardings(state_shapes, mesh)
    b_sh = batch_shardings(batch_shapes, mesh)
    m_sh = {"loss": NamedSharding(mesh, P()), "aux": NamedSharding(mesh, P())}

    step_impl = (cpsl.fused_step_impl if ccfg.fused_step
                 else cpsl.protocol_step_impl)

    def step(state, batch):
        return step_impl(state, batch)

    jitted = jax.jit(step, in_shardings=(st_sh, b_sh),
                     out_shardings=(st_sh, m_sh), donate_argnums=0)
    return jitted, (state_shapes, batch_shapes)


def build_prefill(cfg: ModelConfig, shape: ShapeCfg, mesh):
    sds = jax.ShapeDtypeStruct
    params_shapes = jax.eval_shape(lambda k: api.init(k, cfg),
                                   streams.warmup_key())
    batch_shapes = {"tokens": sds((shape.global_batch, shape.seq_len),
                                  jnp.int32)}
    if cfg.encdec:
        batch_shapes["frames"] = sds(
            (shape.global_batch, cfg.enc_seq, cfg.d_model),
            jnp.dtype(cfg.dtype))
    p_sh = srv_shardings(params_shapes, mesh)
    b_sh = batch_shardings(batch_shapes, mesh, leading_clients=False)

    def step(params, batch):
        return api.prefill(params, batch, cfg, cap=shape.seq_len)

    jitted = jax.jit(step, in_shardings=(p_sh, b_sh))
    return jitted, (params_shapes, batch_shapes)


def build_decode(cfg: ModelConfig, shape: ShapeCfg, mesh, long_ctx: bool):
    sds = jax.ShapeDtypeStruct
    gb, S = shape.global_batch, shape.seq_len
    params_shapes = jax.eval_shape(lambda k: api.init(k, cfg),
                                   streams.warmup_key())
    if cfg.encdec:
        def mkcache():
            b = {"tokens": jnp.zeros((gb, 8), jnp.int32),
                 "frames": jnp.zeros((gb, cfg.enc_seq, cfg.d_model),
                                     jnp.dtype(cfg.dtype))}
            return whp.prefill(params := api.init(streams.warmup_key(), cfg),
                               b, cfg, cap=S)[1]
        cache_shapes = jax.eval_shape(mkcache)
    else:
        cache_shapes = jax.eval_shape(
            lambda: tfm.init_cache(cfg, gb, S, long_ctx))
    tok_shapes = sds((gb,), jnp.int32)
    pos_shape = sds((), jnp.int32)
    p_sh = srv_shardings(params_shapes, mesh)
    c_sh = cache_shardings(cache_shapes, mesh, long_ctx)
    ca = _client_axes(mesh, gb)
    t_sh = NamedSharding(mesh, P(ca if ca else None))

    def step(params, cache, tokens, pos):
        return api.decode_step(params, cache, tokens, pos, cfg)

    vocab_ax = "model" if cfg.vocab_size % mesh.shape["model"] == 0 else None
    jitted = jax.jit(step, in_shardings=(p_sh, c_sh, t_sh,
                                         NamedSharding(mesh, P())),
                     out_shardings=(NamedSharding(mesh, P(
                         ca if ca else None, vocab_ax)), c_sh),
                     donate_argnums=1)
    return jitted, (params_shapes, cache_shapes, tok_shapes, pos_shape)


# --------------------------------------------------------------------------
# runner
# --------------------------------------------------------------------------

def apply_overrides(cfg: ModelConfig, overrides):
    kw = {}
    for ov in overrides or []:
        k, v = ov.split("=", 1)
        cur = getattr(cfg, k)
        if isinstance(cur, bool):
            v = v in ("1", "true", "True")
        elif isinstance(cur, int):
            v = int(v)
        elif isinstance(cur, float):
            v = float(v)
        kw[k] = v
    return cfg.replace(**kw) if kw else cfg


def run_cell(arch: str, cell: str, mesh_name: str, out_dir: str,
             overrides=None, tag: str = "", cut: int = None,
             cluster_size: int = None, profile: str = None,
             ccfg_over=None) -> dict:
    t_start = time.time()
    cfg = apply_overrides(registry.get(arch), overrides)
    shape = SHAPES[cell]
    multi_pod = mesh_name == "pod2"
    if mesh_name == "tiny":
        mesh = jax.make_mesh((2, 4), ("data", "model"))
    else:
        mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.size

    if profile is None:
        # production defaults: train cells use the pure-FSDP layout (batch
        # over all chips; activations and weight gathers halve with bf16
        # params + f32 masters); serving cells use TP.
        profile = "fsdp" if shape.kind == "train" else "tp"
    with pt.use_mesh(mesh, profile=profile):
        if shape.kind == "train":
            K = cluster_size or (32 if multi_pod else 16)
            if mesh_name == "tiny":
                K = 8
            if cfg.loss_chunk == 0:
                cfg = cfg.replace(loss_chunk=2048)   # chunked CE (prod default)
            if cfg.param_dtype == "float32":
                cfg = cfg.replace(param_dtype="bfloat16")
            v = cut or default_cut(cfg)
            explicit_rg = any(o.startswith("remat_group=")
                              for o in (overrides or []))
            if cfg.remat_group == 1 and cfg.pattern and not cfg.encdec \
                    and not explicit_rg:
                from repro.core.splitting import _split_cfgs
                _, srv_cfg = _split_cfgs(cfg, v)
                cfg = cfg.replace(remat_group=best_remat_group(
                    max(srv_cfg.n_periods, 1)))
            jitted, shapes = build_train(
                cfg, shape, mesh, v, K,
                microbatches=DEFAULT_MICROBATCHES.get(arch, 1),
                ccfg_over=ccfg_over)
        elif shape.kind == "prefill":
            jitted, shapes = build_prefill(cfg, shape, mesh)
        else:
            jitted, shapes = build_decode(cfg, shape, mesh,
                                          long_ctx=cell == "long_500k")
        t0 = time.time()
        lowered = jitted.lower(*shapes)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    rec = {"arch": arch, "cell": cell, "mesh": mesh_name, "tag": tag,
           "profile": profile, "ccfg": list(ccfg_over or []),
           "n_devices": n_dev, "lower_s": round(t_lower, 2),
           "compile_s": round(t_compile, 2),
           "overrides": list(overrides or [])}
    try:
        ma = compiled.memory_analysis()
        rec["memory"] = {
            "argument_bytes_per_device": ma.argument_size_in_bytes,
            "output_bytes_per_device": ma.output_size_in_bytes,
            "temp_bytes_per_device": ma.temp_size_in_bytes,
            "alias_bytes_per_device": ma.alias_size_in_bytes,
            "peak_bytes_per_device": (ma.argument_size_in_bytes
                                      + ma.output_size_in_bytes
                                      + ma.temp_size_in_bytes
                                      - ma.alias_size_in_bytes),
        }
    except Exception as e:                      # pragma: no cover
        rec["memory"] = {"error": str(e)}
    try:
        ca = compiled.cost_analysis()
        rec["xla_cost"] = {"flops": ca.get("flops", -1.0),
                           "bytes_accessed": ca.get("bytes accessed", -1.0)}
    except Exception as e:                      # pragma: no cover
        rec["xla_cost"] = {"error": str(e)}
    parsed = hlo_analysis.report(compiled.as_text())
    rec["parsed"] = parsed
    rl = roofline_terms(parsed, n_dev, cfg, shape)
    rec["roofline"] = rl.to_dict()
    rec["total_s"] = round(time.time() - t_start, 2)

    os.makedirs(out_dir, exist_ok=True)
    suffix = f"__{tag}" if tag else ""
    fn = os.path.join(out_dir, f"{arch}__{cell}__{mesh_name}{suffix}.json")
    with open(fn, "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--cell", default=None,
                    choices=list(SHAPES) + [None])
    ap.add_argument("--mesh", default="pod1",
                    choices=["pod1", "pod2", "tiny"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--override", action="append", default=[])
    ap.add_argument("--tag", default="")
    ap.add_argument("--cut", type=int, default=None)
    ap.add_argument("--cluster-size", type=int, default=None)
    ap.add_argument("--profile", default=None, choices=["tp", "fsdp"])
    ap.add_argument("--ccfg", action="append", default=[],
                    help="CPSLConfig overrides, e.g. fused_step=False")
    args = ap.parse_args()

    cells = []
    if args.all:
        for arch in registry.list_archs():
            for cell in registry.cells(arch):
                cells.append((arch, cell))
    else:
        assert args.arch and args.cell
        cells = [(args.arch, args.cell)]

    failures = []
    for arch, cell in cells:
        try:
            rec = run_cell(arch, cell, args.mesh, args.out,
                           overrides=args.override, tag=args.tag,
                           cut=args.cut, cluster_size=args.cluster_size,
                           profile=args.profile, ccfg_over=args.ccfg)
            rl = rec["roofline"]
            print(f"[OK] {arch:24s} {cell:12s} {args.mesh}: "
                  f"compile {rec['compile_s']}s "
                  f"mem/dev {rec['memory'].get('peak_bytes_per_device', -1)/1e9:.2f}GB "
                  f"compute {rl['compute_s']*1e3:.2f}ms "
                  f"mem {rl['memory_s']*1e3:.2f}ms "
                  f"coll {rl['collective_s']*1e3:.2f}ms "
                  f"-> {rl['bottleneck']}", flush=True)
        except Exception as e:
            failures.append((arch, cell, str(e)))
            print(f"[FAIL] {arch} {cell}: {e}", flush=True)
            traceback.print_exc()
    if failures:
        raise SystemExit(f"{len(failures)} cells failed")


if __name__ == "__main__":
    main()
