"""Production mesh construction.

Defined as FUNCTIONS so importing this module never touches jax device
state (the dry-run must set XLA_FLAGS before first jax init).

Single pod:  (data=16, model=16)            = 256 chips (v5e pod)
Multi-pod:   (pod=2, data=16, model=16)     = 512 chips

The 'pod' axis extends client/data parallelism across the DCN/ICI pod
boundary; 'model' is the intra-pod TP axis (fastest ICI links).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1, pods: int = 1):
    """Small mesh over however many (host) devices exist — for tests."""
    n = len(jax.devices())
    assert pods * data * model <= n, (pods, data, model, n)
    if pods > 1:
        return jax.make_mesh((pods, data, model), ("pod", "data", "model"))
    return jax.make_mesh((data, model), ("data", "model"))


# TPU v5e hardware constants (roofline):
PEAK_FLOPS_BF16 = 197e12       # per chip
HBM_BW = 819e9                 # bytes/s per chip
ICI_BW = 50e9                  # bytes/s per link
