"""Serving driver: batched prefill + decode.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma2-2b --reduced \
        --batch 4 --prompt-len 16 --steps 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro import streams
from repro.configs import registry
from repro.models import api
from repro.serving.engine import ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="smoke-scale config (CPU-friendly)")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--steps", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = registry.get(args.arch)
    if args.reduced:
        cfg = registry.reduce_for_smoke(cfg)
    params = api.init(streams.model_key(args.seed), cfg)
    eng = ServeEngine(cfg, params, cap=args.prompt_len + args.steps)
    batch = {"tokens": jax.random.randint(
        streams.sampler_key(1), (args.batch, args.prompt_len), 0,
        cfg.vocab_size)}
    if cfg.encdec:
        batch["frames"] = jnp.zeros(
            (args.batch, cfg.enc_seq, cfg.d_model), jnp.dtype(cfg.dtype))
    t0 = time.time()
    out = eng.generate(batch, steps=args.steps,
                       temperature=args.temperature,
                       key=streams.sampler_key(2))
    dt = time.time() - t0
    print(f"{args.arch}: {out.shape[0]}x{out.shape[1]} tokens in {dt:.2f}s"
          f" ({out.size/dt:.1f} tok/s)")
    print("first row:", out[0].tolist())


if __name__ == "__main__":
    main()
