"""Roofline term derivation (deliverable g).

Per (arch x shape x mesh) cell, from the compiled dry-run artifact:

    compute term    = HLO_FLOPs_global / (chips * 197e12)     [bf16 peak]
    memory term     = HLO_bytes_global / (chips * 819e9)      [HBM BW]
    collective term = collective_bytes_global / (chips * 50e9) [ICI link]

HLO_FLOPs/bytes come from the loop-aware HLO parse (per-device x chips);
MODEL_FLOPS = 6*N_active*D (train) / 2*N_active*D (prefill) /
2*N_active*B (decode) is the "useful work" yardstick — the ratio
MODEL_FLOPS / HLO_FLOPs exposes remat recompute, causal-mask overcompute
and MoE dispatch overhead.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

from repro.configs.base import ModelConfig, ShapeCfg
from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16


def active_matmul_params(cfg: ModelConfig) -> float:
    """Parameters in matmuls a token flows through (MoE: top-k + shared
    experts only; embedding gather excluded; LM head included)."""
    d = cfg.d_model
    total = 0.0
    for spec in cfg.layer_specs():
        if spec.mixer == "attn":
            hd = cfg.resolved_head_dim
            if cfg.attn_kind == "mla":
                m = cfg.mla
                total += (d * cfg.n_heads * (m.qk_nope_head_dim
                                             + m.qk_rope_head_dim)
                          + d * (m.kv_lora_rank + m.qk_rope_head_dim)
                          + m.kv_lora_rank * cfg.n_heads
                          * (m.qk_nope_head_dim + m.v_head_dim)
                          + cfg.n_heads * m.v_head_dim * d)
            else:
                total += (d * cfg.n_heads * hd + 2 * d * cfg.n_kv_heads * hd
                          + cfg.n_heads * hd * d)
        else:
            s = cfg.ssm
            d_inner = s.expand * d
            H = d_inner // s.headdim
            total += d * (2 * d_inner + 2 * s.ngroups * s.d_state + H) \
                + d_inner * d
        if spec.ffn == "dense":
            total += (3 if cfg.glu else 2) * d * cfg.d_ff
        elif spec.ffn == "moe":
            m = cfg.moe
            total += (3 if cfg.glu else 2) * d * m.d_ff_expert \
                * (m.top_k + m.n_shared_experts)
    total += d * cfg.vocab_size        # LM head
    if cfg.encdec:
        # decoder cross-attn already counted via layer_specs? enc-dec
        # specs cover n_layers entries; cross-attn adds ~1 more attn block
        # per decoder layer.
        hd = cfg.resolved_head_dim
        n_dec = cfg.n_layers - cfg.n_enc_layers
        total += n_dec * (d * cfg.n_heads * hd + 2 * d * cfg.n_kv_heads * hd
                          + cfg.n_heads * hd * d)
    return total


def attention_flops_per_token(cfg: ModelConfig, ctx: int) -> float:
    """Score+PV flops per generated/processed token at context ctx."""
    total = 0.0
    for spec in cfg.layer_specs():
        if spec.mixer != "attn":
            # SSD state flops per token
            s = cfg.ssm
            d_inner = s.expand * cfg.d_model
            H = d_inner // s.headdim
            total += 4 * H * s.d_state * s.headdim
            continue
        eff = min(ctx, spec.window) if spec.window else ctx
        if cfg.attn_kind == "mla":
            m = cfg.mla
            total += 2 * eff * cfg.n_heads * (m.qk_nope_head_dim
                                              + m.qk_rope_head_dim
                                              + m.v_head_dim)
        else:
            total += 2 * eff * cfg.n_heads * cfg.resolved_head_dim * 2
    return total


def model_flops(cfg: ModelConfig, shape: ShapeCfg) -> float:
    N = active_matmul_params(cfg)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        # causal average context = S/2
        attn = attention_flops_per_token(cfg, shape.seq_len // 2) * tokens
        return 6.0 * N * tokens + 3.0 * attn
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        attn = attention_flops_per_token(cfg, shape.seq_len // 2) * tokens
        return 2.0 * N * tokens + attn
    # decode: one token per sequence
    attn = attention_flops_per_token(cfg, shape.seq_len) * shape.global_batch
    return 2.0 * N * shape.global_batch + attn


@dataclass
class Roofline:
    compute_s: float
    memory_s: float
    collective_s: float
    model_flops: float
    hlo_flops_global: float
    useful_ratio: float
    bottleneck: str

    def to_dict(self):
        return dict(compute_s=self.compute_s, memory_s=self.memory_s,
                    collective_s=self.collective_s,
                    model_flops=self.model_flops,
                    hlo_flops_global=self.hlo_flops_global,
                    useful_ratio=self.useful_ratio,
                    bottleneck=self.bottleneck)


def roofline_terms(parsed: dict, n_devices: int, cfg: ModelConfig,
                   shape: ShapeCfg) -> Roofline:
    flops_g = parsed["parsed_flops_per_device"] * n_devices
    bytes_g = parsed["parsed_hbm_bytes_per_device"] * n_devices
    coll_g = parsed["collective_bytes_per_device"] * n_devices
    compute_s = flops_g / (n_devices * PEAK_FLOPS_BF16)
    memory_s = bytes_g / (n_devices * HBM_BW)
    coll_s = coll_g / (n_devices * ICI_BW)
    mf = model_flops(cfg, shape)
    terms = {"compute": compute_s, "memory": memory_s, "collective": coll_s}
    bottleneck = max(terms, key=terms.get)
    return Roofline(compute_s, memory_s, coll_s, mf, flops_g,
                    mf / max(flops_g, 1.0), bottleneck)
