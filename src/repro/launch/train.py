"""CPSL training driver.

End-to-end: synthetic non-IID data -> resource-managed CPSL rounds with
checkpoints and the wireless-latency simulator.

    PYTHONPATH=src python -m repro.launch.train --model lenet --rounds 20
    PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b \
        --reduced --rounds 3 --clusters 2 --cluster-size 2
"""
from __future__ import annotations

import argparse
import json

import jax
import numpy as np

from repro import streams
from repro.configs import registry
from repro.configs.base import CPSLConfig
from repro.core.channel import NetworkCfg
from repro.core.cpsl import CPSL
from repro.core.profile import lenet_profile, lm_profile
from repro.core.resource import saa_cut_selection
from repro.core.splitting import make_split_model
from repro.data.pipeline import CPSLDataset, LMClusterData
from repro.data.synthetic import MarkovLM, non_iid_split, synthetic_mnist
from repro.models import lenet
from repro.train.trainer import CPSLTrainer, TrainerCfg


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--model", default="lenet")
    ap.add_argument("--arch", default=None, help="LM arch id (see registry)")
    ap.add_argument("--reduced", action="store_true",
                    help="smoke-scale LM config (CPU-friendly)")
    ap.add_argument("--rounds", type=int, default=10)
    ap.add_argument("--clusters", type=int, default=6)
    ap.add_argument("--cluster-size", type=int, default=5)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--local-epochs", type=int, default=1)
    ap.add_argument("--cut", type=int, default=None)
    ap.add_argument("--saa", action="store_true",
                    help="select the cut layer with Alg. 2 (SAA)")
    ap.add_argument("--resource", default="gibbs",
                    choices=["gibbs", "random", "heuristic", "fixed"])
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--log", default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    n_devices = args.clusters * args.cluster_size
    ncfg = NetworkCfg(n_devices=n_devices)

    if args.arch:
        cfg = registry.get(args.arch)
        if args.reduced:
            cfg = registry.reduce_for_smoke(cfg)
        prof = lm_profile(cfg, seq=args.seq)
        lm = MarkovLM(cfg.vocab_size, seed=args.seed)
        ds = LMClusterData(lm, n_devices, args.batch, args.seq,
                           seed=args.seed)
        model_id = cfg
    else:
        _ = lenet  # paper model
        xtr, ytr, xte, yte = synthetic_mnist(seed=args.seed)
        idx = non_iid_split(ytr, n_devices=n_devices, seed=args.seed)
        ds = CPSLDataset(xtr, ytr, idx, batch=args.batch)
        prof = lenet_profile()
        model_id = "lenet"

    cut = args.cut
    if args.saa or cut is None:
        cut, means = saa_cut_selection(
            prof, ncfg, B=args.batch, L=args.local_epochs,
            n_clusters=args.clusters, cluster_size=args.cluster_size,
            n_samples=4, gibbs_iters=100, seed=args.seed)
        print(f"[SAA] optimal cut layer v* = {cut} "
              f"(per-cut mean latency: {np.round(means, 2).tolist()})")

    ccfg = CPSLConfig(cut_layer=cut, n_clusters=args.clusters,
                      cluster_size=args.cluster_size,
                      local_epochs=args.local_epochs,
                      batch_per_device=args.batch)
    split = make_split_model(model_id, cut)
    tcfg = TrainerCfg(rounds=args.rounds, ckpt_dir=args.ckpt_dir,
                      resource_mgmt=args.resource, log_path=args.log,
                      seed=args.seed)
    trainer = CPSLTrainer(CPSL(split, ccfg), ds, prof, ncfg, tcfg)
    trainer.run(streams.model_key(args.seed), v=cut)
    for h in trainer.history:
        print(json.dumps(h))


if __name__ == "__main__":
    main()
