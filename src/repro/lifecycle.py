"""Graceful-shutdown plumbing shared by the trainer and the rt workers.

A ``GracefulStop`` turns SIGTERM/SIGINT into a thread-safe flag that
long-running loops poll at their next safe point (round boundary, RPC
boundary) instead of dying mid-write: ``train.trainer.CPSLTrainer``
checkpoints-and-exits on it (preemption safety, tested by the
kill-and-resume test), and ``rt.device`` workers use it to finish the
in-flight RPC and send BYE before leaving.

Signal handlers can only be installed from the main thread; elsewhere
(e.g. a trainer constructed inside a test worker thread) ``install``
degrades to a manually-triggerable flag. Previously-installed handlers
are chained so stacking a GracefulStop on top of a host framework's own
SIGTERM hook doesn't swallow it.
"""
from __future__ import annotations

import signal
import threading
from typing import Iterable


class GracefulStop:
    def __init__(self):
        self._event = threading.Event()
        self._chained = {}

    @property
    def triggered(self) -> bool:
        return self._event.is_set()

    def __bool__(self) -> bool:
        return self.triggered

    def trigger(self, signum=None, frame=None):
        """Signal-handler entrypoint; also callable directly (tests, or
        a parent orchestrator asking a worker loop to wind down)."""
        self._event.set()
        prev = self._chained.get(signum)
        if callable(prev):
            prev(signum, frame)

    def wait(self, timeout: float) -> bool:
        return self._event.wait(timeout)

    def install(self, signals: Iterable[int] = (signal.SIGTERM,)
                ) -> "GracefulStop":
        for sig in signals:
            try:
                prev = signal.signal(sig, self.trigger)
            except ValueError:      # not the main thread
                continue
            if prev not in (signal.SIG_DFL, signal.SIG_IGN, None):
                self._chained[sig] = prev
        return self
