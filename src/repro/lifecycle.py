"""Graceful-shutdown + retry/backoff plumbing shared by the trainer and
the rt runtime.

A ``GracefulStop`` turns SIGTERM/SIGINT into a thread-safe flag that
long-running loops poll at their next safe point (round boundary, RPC
boundary) instead of dying mid-write: ``train.trainer.CPSLTrainer``
checkpoints-and-exits on it (preemption safety, tested by the
kill-and-resume test), and ``rt.device`` workers use it to finish the
in-flight RPC and send BYE before leaving.

Signal handlers can only be installed from the main thread; elsewhere
(e.g. a trainer constructed inside a test worker thread) ``install``
degrades to a manually-triggerable flag. Previously-installed handlers
are chained so stacking a GracefulStop on top of a host framework's own
SIGTERM hook doesn't swallow it.

``Backoff`` / ``retry_sleeps`` / ``retry_budget_s`` centralize the
exponential-backoff arithmetic that used to be scattered (and uncapped)
across the rt stack: the device RPC loop, the worker-reconnect dialer
and the orchestrator's respawn monitor all draw their delays from here,
and ``rt.orchestrator.RTConfig.validate`` uses ``retry_budget_s`` to
refuse configs whose device-side retry budget silently crosses the
server's straggler deadline (the device would still be retrying a phase
the server already gave up on).
"""
from __future__ import annotations

import signal
import threading
from typing import Iterable, List


def retry_sleeps(retries: int, backoff0: float,
                 cap: float = float("inf")) -> List[float]:
    """The sleep before each re-attempt ``a = 1..retries``:
    ``min(backoff0 * 2**(a-1), cap)`` — exponential, capped, and
    monotone non-decreasing (property-tested)."""
    return [min(backoff0 * (2.0 ** a), cap) for a in range(retries)]


def retry_budget_s(timeout_s: float, retries: int, backoff0: float,
                   cap: float = float("inf")) -> float:
    """Worst-case wall-clock one RPC can spend before giving up:
    ``retries + 1`` reply waits of ``timeout_s`` plus the backoff sleeps
    between them. A server phase deadline must exceed this or the two
    ends disagree about who timed out first."""
    return (retries + 1) * timeout_s + sum(retry_sleeps(retries, backoff0,
                                                        cap))


class Backoff:
    """Stateful capped exponential backoff (respawn / reconnect pacing):
    ``next()`` returns the current delay and doubles it up to ``cap``;
    ``reset()`` re-arms after a success."""

    def __init__(self, initial: float = 0.25, cap: float = 5.0):
        self.initial = float(initial)
        self.cap = float(cap)
        self._cur = self.initial

    def next(self) -> float:
        d = self._cur
        self._cur = min(self._cur * 2.0, self.cap)
        return d

    def reset(self):
        self._cur = self.initial


class GracefulStop:
    def __init__(self):
        self._event = threading.Event()
        self._chained = {}

    @property
    def triggered(self) -> bool:
        return self._event.is_set()

    def __bool__(self) -> bool:
        return self.triggered

    def trigger(self, signum=None, frame=None):
        """Signal-handler entrypoint; also callable directly (tests, or
        a parent orchestrator asking a worker loop to wind down)."""
        self._event.set()
        prev = self._chained.get(signum)
        if callable(prev):
            prev(signum, frame)

    def wait(self, timeout: float) -> bool:
        return self._event.wait(timeout)

    def install(self, signals: Iterable[int] = (signal.SIGTERM,)
                ) -> "GracefulStop":
        for sig in signals:
            try:
                prev = signal.signal(sig, self.trigger)
            except ValueError:      # not the main thread
                continue
            if prev not in (signal.SIG_DFL, signal.SIG_IGN, None):
                self._chained[sig] = prev
        return self
