"""Fault-tolerant checkpointing: msgpack+zstd payloads, atomic renames,
async save thread, keep-k GC, per-payload integrity checksums, and
*elastic* restore (arrays are stored as host numpy and re-placed under
whatever mesh/sharding the restoring job uses — a checkpoint written on
one topology restores on another).

Integrity: every file is framed ``b"RCK1" + crc32(payload) + payload``
and the checksum is verified on restore. A latest checkpoint that is
corrupted or truncated (half-written by a crash that beat the atomic
rename, bit-rot, a torn copy) makes ``restore(step=None)`` fall back to
the previous keep-k entry with a ``CheckpointCorrupt`` warning instead
of crashing the resume — an explicit ``step=`` still raises, because
the caller asked for that file specifically. Unframed legacy files are
read without verification.
"""
from __future__ import annotations

import os
import re
import shutil
import struct
import threading
import time
import warnings
from typing import Any, Optional

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

try:
    import zstandard as zstd
except ImportError:          # optional: fall back to stdlib zlib
    zstd = None
import zlib

_ZSTD_MAGIC = b"\x28\xb5\x2f\xfd"
_CKPT_MAGIC = b"RCK1"              # framed: magic + u32 crc32 + payload
_CKPT_HDR = struct.Struct(">4sI")


class CheckpointCorrupt(RuntimeError):
    """A checkpoint file failed its integrity check (bad checksum,
    truncated header, undecodable payload)."""


def frame_blob(payload: bytes) -> bytes:
    return _CKPT_HDR.pack(_CKPT_MAGIC, zlib.crc32(payload)) + payload


def unframe_blob(blob: bytes, name: str = "checkpoint") -> bytes:
    """Verify and strip the integrity frame. Unframed (legacy) blobs
    pass through unverified; framed blobs with a wrong checksum or a
    truncated body raise ``CheckpointCorrupt``."""
    if blob[:4] != _CKPT_MAGIC:
        return blob                # legacy file, no checksum to check
    if len(blob) < _CKPT_HDR.size:
        raise CheckpointCorrupt(f"{name}: truncated header "
                                f"({len(blob)} bytes)")
    _, crc = _CKPT_HDR.unpack(blob[:_CKPT_HDR.size])
    payload = blob[_CKPT_HDR.size:]
    got = zlib.crc32(payload)
    if got != crc:
        raise CheckpointCorrupt(
            f"{name}: checksum mismatch (stored 0x{crc:08x}, computed "
            f"0x{got:08x}) — file is corrupted or torn")
    return payload


def _compress(raw: bytes) -> bytes:
    if zstd is not None:
        return zstd.ZstdCompressor(level=3).compress(raw)
    return zlib.compress(raw, 6)


def _decompress(blob: bytes) -> bytes:
    if blob[:4] == _ZSTD_MAGIC:
        if zstd is None:
            raise RuntimeError("checkpoint is zstd-compressed but the "
                               "zstandard package is not installed")
        return zstd.ZstdDecompressor().decompress(blob)
    return zlib.decompress(blob)


def _path_str(path) -> str:
    parts = []
    for pp in path:
        if hasattr(pp, "key"):
            parts.append(str(pp.key))
        elif hasattr(pp, "idx"):
            parts.append(str(pp.idx))
        else:
            parts.append(str(pp))
    return "/".join(parts)


def _pack_array(a: np.ndarray) -> dict:
    shape = list(a.shape)              # before ascontiguousarray: it
    a = np.ascontiguousarray(a)        # promotes 0-d -> (1,)
    # dtype by NAME: extension dtypes (bfloat16 via ml_dtypes) have
    # opaque .str codes ('V2') that frombuffer can't reconstruct
    return {"dtype": a.dtype.name, "shape": shape, "data": a.tobytes()}


def _unpack_array(d: dict) -> np.ndarray:
    dt = np.dtype(jnp.dtype(d["dtype"]))
    return np.frombuffer(d["data"], dtype=dt).reshape(d["shape"])


def serialize(tree) -> bytes:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    payload = {}
    for path, leaf in flat:
        arr = np.asarray(jax.device_get(leaf))
        payload[_path_str(path)] = _pack_array(arr)
    raw = msgpack.packb(payload, use_bin_type=True)
    return _compress(raw)


def deserialize(blob: bytes, target) -> Any:
    raw = _decompress(blob)
    payload = msgpack.unpackb(raw, raw=False)
    flat, treedef = jax.tree_util.tree_flatten_with_path(target)
    leaves = []
    for path, leaf in flat:
        key = _path_str(path)
        if key not in payload:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        arr = _unpack_array(payload[key])
        want = np.dtype(leaf.dtype) if hasattr(leaf, "dtype") else arr.dtype
        arr = arr.astype(want, copy=False)
        if hasattr(leaf, "sharding") and leaf.sharding is not None \
                and hasattr(leaf.sharding, "mesh"):
            leaves.append(jax.device_put(arr, leaf.sharding))
        else:
            leaves.append(jnp.asarray(arr))
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(target), leaves)


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3,
                 async_save: bool = False):
        self.dir = directory
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        self.restored_step: Optional[int] = None  # set by restore(step=None)
        os.makedirs(directory, exist_ok=True)

    # -- save ----------------------------------------------------------------

    def _write(self, blob: bytes, step: int):
        final = os.path.join(self.dir, f"ckpt_{step:010d}")
        tmp = final + ".tmp"
        with open(tmp, "wb") as f:
            f.write(frame_blob(blob))
            f.flush()
            os.fsync(f.fileno())
        os.rename(tmp, final)          # atomic commit
        self._gc()

    def save(self, state, step: int, block: bool = True):
        blob = serialize(state)        # device_get happens sync (consistent)
        if self.async_save and not block:
            self.wait()
            self._thread = threading.Thread(target=self._write,
                                            args=(blob, step), daemon=True)
            self._thread.start()
        else:
            self._write(blob, step)

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    # -- restore ---------------------------------------------------------------

    def steps(self):
        out = []
        for fn in os.listdir(self.dir):
            m = re.fullmatch(r"ckpt_(\d+)", fn)
            if m:
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        s = self.steps()
        return s[-1] if s else None

    def _load(self, target, step: int):
        """Load + verify one checkpoint file; every failure mode
        (truncation, bad checksum, undecodable payload) surfaces as
        ``CheckpointCorrupt``."""
        name = f"ckpt_{step:010d}"
        with open(os.path.join(self.dir, name), "rb") as f:
            blob = f.read()
        payload = unframe_blob(blob, name=name)
        try:
            return deserialize(payload, target)
        except KeyError:
            raise                      # structure mismatch, not corruption
        except Exception as e:
            raise CheckpointCorrupt(f"{name}: undecodable payload: {e}") \
                from e

    def restore(self, target, step: Optional[int] = None):
        """Restore ``step`` (explicit steps fail loudly on corruption).
        With ``step=None``, walk back from the latest entry: a corrupted
        or truncated checkpoint is skipped with a warning and the
        previous keep-k entry is restored instead — resumes survive a
        damaged last save. Raises only when every entry is corrupt."""
        if step is not None:
            return self._load(target, step)
        steps = self.steps()
        if not steps:
            return None
        err: Optional[CheckpointCorrupt] = None
        for s in reversed(steps):
            try:
                out = self._load(target, s)
            except CheckpointCorrupt as e:
                warnings.warn(
                    f"{e}; falling back to the previous checkpoint",
                    RuntimeWarning)
                err = e
                continue
            self.restored_step = s
            return out
        raise CheckpointCorrupt(
            f"all {len(steps)} checkpoints in {self.dir} are corrupt"
        ) from err

    def _gc(self):
        steps = self.steps()
        for s in steps[:-self.keep] if self.keep else []:
            try:
                os.remove(os.path.join(self.dir, f"ckpt_{s:010d}"))
            except OSError:
                pass
