"""Batched serving engine: prefill + decode with KV/SSM caches.

``serve_step`` (one token for the whole batch) is the unit the decode-shape
dry-runs lower. ``generate`` drives greedy/temperature sampling over a
fixed batch of requests (static shapes — continuous batching would swap
finished rows; here rows finishing early keep decoding into padding, which
is the shape-stable TPU-friendly variant).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import api


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, cap: int = 2048):
        self.cfg, self.params, self.cap = cfg, params, cap
        self._prefill = jax.jit(functools.partial(api.prefill, cfg=cfg,
                                                  cap=cap))
        self._step = jax.jit(functools.partial(api.decode_step, cfg=cfg))

    def prefill(self, batch):
        return self._prefill(self.params, batch)

    def decode(self, cache, tokens, pos):
        return self._step(self.params, cache, tokens, pos)

    def generate(self, batch, steps: int, temperature: float = 0.0,
                 key=None):
        """batch: {"tokens": (B, S_prompt)} (+frames for enc-dec).
        Returns (B, steps) generated tokens."""
        logits, cache = self.prefill(batch)
        S = batch["tokens"].shape[1]
        outs = []
        tok = self._sample(logits, temperature, key, 0)
        outs.append(tok)
        for i in range(steps - 1):
            logits, cache = self.decode(cache, tok, S + i)
            tok = self._sample(logits, temperature, key, i + 1)
            outs.append(tok)
        return jnp.stack(outs, axis=1)

    @staticmethod
    def _sample(logits, temperature, key, i):
        if temperature <= 0 or key is None:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        k = jax.random.fold_in(key, i)
        return jax.random.categorical(k, logits / temperature).astype(
            jnp.int32)
