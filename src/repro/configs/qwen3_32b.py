"""qwen3-32b [dense]: 64L, d=5120, 64H (kv=8, head_dim=128 explicit),
d_ff=25600, vocab=151936, qk_norm, no QKV bias. [hf:Qwen/Qwen3]"""
from repro.configs.base import LayerSpec, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-32b", family="dense",
        d_model=5120, n_layers=64, n_heads=64, n_kv_heads=8, head_dim=128,
        d_ff=25600, vocab_size=151936,
        pattern=(LayerSpec("attn", "dense"),),
        qk_norm=True, tie_embeddings=False, rope_theta=1e6,
    )
