"""Config dataclasses for the repro framework.

A ModelConfig fully describes one architecture in the zoo. Layer stacks are
expressed as an optional unrolled ``prologue`` followed by a periodic
``pattern`` that is scanned ``n_periods`` times (compact HLO => fast SPMD
compiles at 512 devices). Heterogeneous stacks (gemma2 local/global, jamba
1:7 mamba:attn with alternating MoE) are one period of the repeating unit.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class MoECfg:
    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared_experts: int = 0
    group_size: int = 2048          # tokens per dispatch group (GShard-style)
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01


@dataclass(frozen=True)
class MLACfg:
    kv_lora_rank: int = 512
    q_lora_rank: int = 0            # 0 = full-rank q projection (V2-Lite)
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclass(frozen=True)
class SSMCfg:
    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    headdim: int = 64
    ngroups: int = 1
    chunk_size: int = 256
    dt_min: float = 0.001
    dt_max: float = 0.1


@dataclass(frozen=True)
class LayerSpec:
    """Kinds for one layer: mixer in {attn, mamba}, ffn in {dense, moe, none}.

    ``window`` > 0 selects sliding-window attention for this layer (gemma2
    local layers). ``window == 0`` means full (global) attention.
    """
    mixer: str = "attn"
    ffn: str = "dense"
    window: int = 0


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | encdec | vlm | audio | cnn
    d_model: int
    n_layers: int
    n_heads: int = 0
    n_kv_heads: int = 0
    head_dim: int = 0
    d_ff: int = 0
    vocab_size: int = 0
    prologue: Tuple[LayerSpec, ...] = ()
    pattern: Tuple[LayerSpec, ...] = (LayerSpec("attn", "dense"),)
    # attention details
    attn_kind: str = "gqa"           # gqa | mla
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 1e4
    attn_softcap: float = 0.0        # gemma2: 50.0
    final_softcap: float = 0.0       # gemma2: 30.0
    norm_kind: str = "rmsnorm"       # rmsnorm | layernorm
    norm_eps: float = 1e-6
    act: str = "silu"                # silu | gelu
    glu: bool = True                 # gated MLP (swiglu/geglu) vs plain 2-matmul
    post_norm: bool = False          # gemma2-style post-sublayer norms
    tie_embeddings: bool = True
    embed_scale: bool = False        # gemma: multiply embeddings by sqrt(d)
    # sub-configs
    moe: Optional[MoECfg] = None
    mla: Optional[MLACfg] = None
    ssm: Optional[SSMCfg] = None
    # encoder-decoder (whisper)
    encdec: bool = False
    n_enc_layers: int = 0
    enc_seq: int = 1500              # precomputed frame embeddings (frontend stub)
    # numerics / implementation selection
    dtype: str = "bfloat16"          # compute dtype
    param_dtype: str = "float32"
    attn_impl: str = "chunked"       # naive | chunked | pallas
    ssd_impl: str = "chunked"        # scan | chunked | pallas
    remat: bool = True
    remat_group: int = 1             # >1: two-level (sqrt) remat — the
                                     # layer scan saves one residual per
                                     # GROUP of this many periods
    q_chunk: int = 512
    kv_chunk: int = 1024
    loss_chunk: int = 0              # >0: chunked CE (never materializes
                                     # the full (tokens, vocab) logits)

    # -- derived -----------------------------------------------------------
    @property
    def n_periods(self) -> int:
        body = self.n_layers - len(self.prologue)
        if self.pattern:
            assert body % len(self.pattern) == 0, (
                f"{self.name}: {body} body layers not divisible by pattern "
                f"of {len(self.pattern)}")
            return body // len(self.pattern)
        return 0

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // max(self.n_heads, 1)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def layer_specs(self) -> Tuple[LayerSpec, ...]:
        """Flattened per-layer specs, prologue first."""
        return self.prologue + self.pattern * self.n_periods


@dataclass(frozen=True)
class CPSLConfig:
    """Cluster-based Parallel Split Learning hyper-parameters (paper §IV)."""
    cut_layer: int = 2               # v: blocks [0, v) are device-side
    n_clusters: int = 6              # M
    cluster_size: int = 5            # K_m devices per cluster
    local_epochs: int = 1            # L
    lr_device: float = 0.05          # eta_d
    lr_server: float = 0.25          # eta_e
    batch_per_device: int = 16       # B
    optimizer: str = "sgd"           # sgd | momentum | adamw
    momentum: float = 0.0
    weight_decay: float = 0.0
    fused_step: bool = True          # fused autodiff vs explicit 2-phase protocol
    fused_round: bool = False        # whole-round lax.scan path: trainers use
                                     # CPSL.run_round_fused (device-resident
                                     # data, in-jit batch gather, FedAvg folded
                                     # into the scan) instead of per-step jits
    fused_round_unroll: int = 0      # scan unroll for the fused round; 0 = full
                                     # unroll (XLA:CPU lowers conv grads inside
                                     # while-loop bodies to its naive emitter,
                                     # ~40x slower — measured in bench_round)
    unroll_clients: bool = False     # trace-time unroll of the K-client device
                                     # pass instead of jax.vmap: vmap over
                                     # per-client weights lowers conv grads to
                                     # grouped convolutions (~10x slower on
                                     # XLA:CPU); ULP-level lowering differences
                                     # vs the vmapped form (tested)
    microbatches: int = 1            # grad-accumulation splits of B
    share_device_params: bool = False  # L==1 fast path (beyond-paper)
    straggler_dropout: float = 0.0   # fraction of clients allowed to miss FedAvg
    compress_uploads: str = "none"   # none | topk | int8 (device-model uploads)
    compress_topk: float = 0.1
    scan_rounds: bool = False        # run_training_fused round axis as a
                                     # lax.scan (R-independent compile) instead
                                     # of a trace-time unroll; needs a
                                     # loop-body-safe lowering on XLA:CPU —
                                     # pair with conv_impl="im2col" (direct
                                     # conv grads in while bodies hit the
                                     # naive emitter, ~36x, measured)
    conv_impl: str = "direct"        # lenet conv lowering: "direct" (lax conv,
                                     # fastest solo) | "im2col" (matmul form —
                                     # batches cleanly under vmap over client/
                                     # replica weights and stays fast inside
                                     # scans; forward bit-identical, tested).
                                     # Consumed at split-model build time
                                     # (make_split_model("lenet", v,
                                     # conv_impl=...))


@dataclass(frozen=True)
class FleetConfig:
    """Experiment fleet: E = len(seeds) x len(cluster_sizes) x len(lrs)
    CPSL training replicas executed as ONE batched program
    (``CPSL.run_fleet``; built/driven by ``train.trainer.FleetRunner``).

    Replicas differ only in data — per-replica seeds (init + non-IID
    shard draws + batch streams), cluster layouts padded to the grid's
    (max M, max K) with masks, and learning rates applied as traced
    scalars — so the whole grid shares one XLA compile."""
    rounds: int = 10
    seeds: Tuple[int, ...] = (0,)
    cluster_sizes: Tuple[int, ...] = (5,)   # N_m grid axis (fig. 6)
    lr_scales: Tuple[float, ...] = ()       # lr grid axis, multiplying the
                                            # CPSLConfig lrs; () = base lr only
    n_devices: int = 30                     # N (shards drawn per seed)
    eval_every: int = 0                     # in-jit eval cadence; 0 = off
    samples_per_device: int = 180           # non-IID shard size

    @property
    def n_replicas(self) -> int:
        return (len(self.seeds) * len(self.cluster_sizes)
                * max(len(self.lr_scales), 1))

    def replace(self, **kw):
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class MeshConfig:
    data: int = 16
    model: int = 16
    pods: int = 1                    # >1 adds leading "pod" axis

    @property
    def n_devices(self) -> int:
        return self.data * self.model * self.pods


@dataclass(frozen=True)
class ShapeCfg:
    """One input-shape cell from the assignment."""
    name: str                        # train_4k | prefill_32k | decode_32k | long_500k
    seq_len: int
    global_batch: int
    kind: str                        # train | prefill | decode


SHAPES = {
    "train_4k":    ShapeCfg("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCfg("prefill_32k", 32768, 32, "prefill"),
    "decode_32k":  ShapeCfg("decode_32k", 32768, 128, "decode"),
    "long_500k":   ShapeCfg("long_500k", 524288, 1, "decode"),
}


@dataclass
class SimCfg:
    """Dynamic-network simulation (``repro.sim``): round/timescale layout
    of one end-to-end "train under dynamics" run."""
    rounds: int = 20                 # small-timescale slots == CPSL rounds
    epoch_len: int = 5               # rounds per large timescale epoch (Alg. 2 rerun)
    cluster_size: int = 5            # target K; clusters shrink under churn
    saa_samples: int = 3             # J network samples per SAA evaluation
    saa_gibbs_iters: int = 40        # Gibbs iters inside the SAA inner loop
    gibbs_iters: int = 120           # Gibbs iters for the per-slot plan
    gibbs_chains: int = 1            # lockstep Gibbs replicas per plan
                                     # (best-of-R; chain 0 == single-chain
                                     # stream, so 1 reproduces the looped
                                     # planner bit-exactly)
    cuts: Optional[Tuple[int, ...]] = None  # candidate cut layers (None = all)
    trace_path: Optional[str] = None # JSONL trace destination
    seed: int = 0
    # -- population-scale planning knobs -----------------------------------
    plan_mode: str = "flat"          # "flat" = one Gibbs over all devices;
                                     # "bucketed" = hierarchical two-level
                                     # clustering (bucket_devices + per-
                                     # bucket lockstep Gibbs). With
                                     # n <= bucket_size the bucketed plan
                                     # is bit-identical to flat (tested)
    bucket_size: int = 320           # target devices per coarse bucket
    spectrum_topk: int = 0           # >0: greedy Alg. 3 argmins scan only
                                     # the k worst-score devices per step
                                     # (k >= cluster size is exact)

    def replace(self, **kw):
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class SimFleetCfg:
    """Episode fleet: E = cuts x policies x cluster_sizes x seeds dynamic-
    network episodes priced as ONE jitted/vmapped program
    (``repro.sim.fleet.SimFleetRunner``).

    Episodes differ only in data — per-episode profile constants (cut),
    policy/cluster-size selectors, device means and innovation streams
    (seed) — so the whole grid shares one XLA compile. Episodes with the
    same ``seed`` share their network realization (means + fading/compute
    innovations, and the churn/planner draws), which gives
    common-random-number coupling across the other grid axes (the fig. 7
    cut sweep and the fig. 8(b) three-arm comparison rely on it).

    The ``proposed`` policy is the paper's full two-timescale controller
    run inside the jit: per-slot Gibbs clustering with embedded greedy
    (Alg. 3/4, ``gibbs_iters`` sweeps, best of ``gibbs_chains`` lockstep
    chains) and — when ``saa_cuts`` is set — Alg. 2 SAA cut re-selection
    every ``epoch_len`` slots over the (cut x sample x chain) grid
    around the episode's device means. ``saa_cuts=None`` keeps the
    episode's spec cut fixed (pure small-timescale planning)."""
    rounds: int = 20                        # slots T per episode
    seeds: Tuple[int, ...] = (0,)
    policies: Tuple[str, ...] = ("greedy",)  # equal | greedy | proposed
    cluster_sizes: Tuple[int, ...] = (5,)   # target K per episode
    cuts: Tuple[int, ...] = (3,)            # cut layer v per episode
    batch_per_device: int = 16              # B in the eq. 15-25 cost model
    local_epochs: int = 1                   # L
    mean_seed: Optional[int] = None         # shared device_means seed;
                                            # None = per-episode seed
    # -- proposed-policy (two-timescale controller) knobs ------------------
    epoch_len: int = 5                      # slots per large-timescale epoch
    gibbs_iters: int = 120                  # Alg. 4 sweeps per slot plan
    gibbs_chains: int = 1                   # best-of-R lockstep chains
    gibbs_delta: float = 1e-4               # Metropolis temperature
    saa_samples: int = 3                    # J network samples per SAA cell
    saa_gibbs_iters: int = 40               # Alg. 4 sweeps inside SAA
    saa_cuts: Optional[Tuple[int, ...]] = None  # Alg. 2 candidate cuts;
                                            # None = no SAA (fixed spec cut)
    # -- stochastic-churn support ------------------------------------------
    n_reserve: int = 0                      # reserve device rows for
                                            # Bernoulli arrivals (p_arrive)
    min_devices_floor: bool = False         # honor DynamicsCfg.min_devices
                                            # (opt-in: False keeps every
                                            # departure/depletion executing)
    cost_chunk: int = 0                     # >0: stream the in-jit greedy
                                            # candidate tensors through
                                            # lax.map in tiles of this many
                                            # clusters (bounds peak memory;
                                            # decisions unchanged, tested)

    @property
    def n_episodes(self) -> int:
        return (len(self.cuts) * len(self.policies)
                * len(self.cluster_sizes) * len(self.seeds))

    def replace(self, **kw):
        return dataclasses.replace(self, **kw)
