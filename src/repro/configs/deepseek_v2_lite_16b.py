"""deepseek-v2-lite-16b [moe]: 27L, d=2048, 16H, MLA (kv_lora=512, rope 64,
nope 128, v 128), vocab=102400; MoE: 2 shared + 64 routed top-6,
d_ff_expert=1408; first layer dense (d_ff=10944). [arXiv:2405.04434; hf]

Assignment-line note: the line says both "64e" and "160 routed"; the HF
V2-LITE config is 64 routed + 2 shared — implemented here (see DESIGN.md).
"""
from repro.configs.base import LayerSpec, MLACfg, MoECfg, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v2-lite-16b", family="moe",
        d_model=2048, n_layers=27, n_heads=16, n_kv_heads=16,
        d_ff=10944, vocab_size=102400,
        prologue=(LayerSpec("attn", "dense"),),       # first_k_dense = 1
        pattern=(LayerSpec("attn", "moe"),),          # 26 MoE layers
        attn_kind="mla",
        mla=MLACfg(kv_lora_rank=512, q_lora_rank=0, qk_nope_head_dim=128,
                   qk_rope_head_dim=64, v_head_dim=128),
        moe=MoECfg(n_experts=64, top_k=6, d_ff_expert=1408,
                   n_shared_experts=2, group_size=512),
        tie_embeddings=False, rope_theta=1e4,
    )
