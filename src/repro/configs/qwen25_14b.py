"""qwen2.5-14b [dense]: 48L, d=5120, 40H (kv=8, head_dim=128), d_ff=13824,
vocab=152064, QKV bias. [hf:Qwen/Qwen2.5]"""
from repro.configs.base import LayerSpec, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2.5-14b", family="dense",
        d_model=5120, n_layers=48, n_heads=40, n_kv_heads=8, head_dim=128,
        d_ff=13824, vocab_size=152064,
        pattern=(LayerSpec("attn", "dense"),),
        qkv_bias=True, tie_embeddings=False, rope_theta=1e6,
    )
