"""qwen2-0.5b [dense]: 24L, d=896, 14H (kv=2, head_dim=64), d_ff=4864,
vocab=151936, QKV bias, tied embeddings. [arXiv:2407.10671]"""
from repro.configs.base import LayerSpec, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-0.5b", family="dense",
        d_model=896, n_layers=24, n_heads=14, n_kv_heads=2, head_dim=64,
        d_ff=4864, vocab_size=151936,
        pattern=(LayerSpec("attn", "dense"),),
        qkv_bias=True, tie_embeddings=True, rope_theta=1e6,
    )
