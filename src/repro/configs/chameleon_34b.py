"""chameleon-34b [vlm]: early-fusion, 48L, d=8192, 64H (kv=8), d_ff=22016,
vocab=65536 (includes VQ image-token codes — the VQ tokenizer is the stub;
inputs are ordinary token ids). qk-norm per the paper. [arXiv:2405.09818]
"""
from repro.configs.base import LayerSpec, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="chameleon-34b", family="vlm",
        d_model=8192, n_layers=48, n_heads=64, n_kv_heads=8, head_dim=128,
        d_ff=22016, vocab_size=65536,
        pattern=(LayerSpec("attn", "dense"),),
        qk_norm=True, tie_embeddings=False, rope_theta=1e4,
    )
