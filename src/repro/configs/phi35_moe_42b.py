"""phi3.5-moe-42b-a6.6b [moe]: 32L, d=4096, 32H (kv=8), 16 experts top-2,
d_ff_expert=6400, vocab=32064. [hf:microsoft/Phi-3.5-MoE-instruct]
"""
from repro.configs.base import LayerSpec, MoECfg, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="phi3.5-moe-42b-a6.6b", family="moe",
        d_model=4096, n_layers=32, n_heads=32, n_kv_heads=8, head_dim=128,
        d_ff=6400, vocab_size=32064,
        pattern=(LayerSpec("attn", "moe"),),
        moe=MoECfg(n_experts=16, top_k=2, d_ff_expert=6400, group_size=512),
        tie_embeddings=False, rope_theta=1e4,
    )
