"""mamba2-2.7b [ssm]: 64L, d=2560, attention-free, ssm_state=128,
headdim=64, expand=2, vocab=50280. SSD (state-space duality).
[arXiv:2405.21060]
"""
from repro.configs.base import LayerSpec, ModelConfig, SSMCfg


def config() -> ModelConfig:
    # vocab: 50280 logical (GPT-NeoX tokenizer) padded to 50304 — the
    # standard NeoX padded table size — so the vocab dim shards over
    # 16-way TP (50280 % 16 != 0 would force a replicated LM head).
    return ModelConfig(
        name="mamba2-2.7b", family="ssm",
        d_model=2560, n_layers=64, n_heads=0, n_kv_heads=0, d_ff=0,
        vocab_size=50304,
        pattern=(LayerSpec("mamba", "none"),),
        ssm=SSMCfg(d_state=128, d_conv=4, expand=2, headdim=64, ngroups=1,
                   chunk_size=256),
        tie_embeddings=True,
    )
