"""Architecture registry: ``--arch <id>`` lookup, input specs per shape
cell, and reduced configs for CPU smoke tests.

The 4 shape cells (assignment):
    train_4k:    seq 4096,   global_batch 256  -> CPSL train_step
    prefill_32k: seq 32768,  global_batch 32   -> prefill_step
    decode_32k:  seq 32768,  global_batch 128  -> serve_step (1 new token)
    long_500k:   seq 524288, global_batch 1    -> serve_step; only for
                 sub-quadratic archs (mamba2, jamba) — see DESIGN.md.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs import (chameleon_34b, deepseek_v2_lite_16b, gemma2_2b,
                           jamba_v01_52b, mamba2_2p7b, phi35_moe_42b,
                           qwen2_05b, qwen25_14b, qwen3_32b, whisper_small)
from repro.configs.base import (LayerSpec, MLACfg, ModelConfig, MoECfg,
                                SHAPES, SSMCfg, ShapeCfg)

ARCHS = {
    "whisper-small": whisper_small.config,
    "chameleon-34b": chameleon_34b.config,
    "deepseek-v2-lite-16b": deepseek_v2_lite_16b.config,
    "phi3.5-moe-42b-a6.6b": phi35_moe_42b.config,
    "mamba2-2.7b": mamba2_2p7b.config,
    "jamba-v0.1-52b": jamba_v01_52b.config,
    "gemma2-2b": gemma2_2b.config,
    "qwen2.5-14b": qwen25_14b.config,
    "qwen3-32b": qwen3_32b.config,
    "qwen2-0.5b": qwen2_05b.config,
}

# archs eligible for the long_500k cell (sub-quadratic sequence mixing)
LONG_CTX_ARCHS = {"mamba2-2.7b", "jamba-v0.1-52b"}


def get(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    return ARCHS[name]()


def list_archs():
    return sorted(ARCHS)


def cells(arch: str):
    """Shape cells applicable to this arch."""
    out = ["train_4k", "prefill_32k", "decode_32k"]
    if arch in LONG_CTX_ARCHS:
        out.append("long_500k")
    return out


# --------------------------------------------------------------------------
# input specs (ShapeDtypeStruct stand-ins — no allocation)
# --------------------------------------------------------------------------

def input_specs(cfg: ModelConfig, shape: ShapeCfg) -> Dict:
    """Abstract input batch for the given shape cell.

    train/prefill: token batch (+ frames for enc-dec).
    decode: token column; the (large) cache spec is built separately via
    ``jax.eval_shape`` over the cache initializer (see launch/dryrun.py).
    """
    gb, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    sds = jax.ShapeDtypeStruct
    if shape.kind == "train":
        batch = {"tokens": sds((gb, S), i32), "labels": sds((gb, S), i32)}
        if cfg.encdec:
            batch["frames"] = sds((gb, cfg.enc_seq, cfg.d_model),
                                  jnp.dtype(cfg.dtype))
        return batch
    if shape.kind == "prefill":
        batch = {"tokens": sds((gb, S), i32)}
        if cfg.encdec:
            batch["frames"] = sds((gb, cfg.enc_seq, cfg.d_model),
                                  jnp.dtype(cfg.dtype))
        return batch
    # decode: one new token at position S-1 given a cache of capacity S
    return {"tokens": sds((gb,), i32)}


# --------------------------------------------------------------------------
# reduced configs for CPU smoke tests
# --------------------------------------------------------------------------

def reduce_for_smoke(cfg: ModelConfig) -> ModelConfig:
    """Same family/features, tiny dims: runs a forward + train step on CPU."""
    kw = dict(
        d_model=64,
        n_heads=4 if cfg.n_heads else 0,
        n_kv_heads=min(cfg.n_kv_heads, 2) if cfg.n_kv_heads else 0,
        head_dim=16 if cfg.head_dim else 0,
        d_ff=128 if cfg.d_ff else 0,
        vocab_size=211,
        n_layers=len(cfg.prologue) + 2 * len(cfg.pattern),
        remat=False,
        q_chunk=8, kv_chunk=8,
    )
    if cfg.moe is not None:
        # ample capacity: smoke tests check exact equivalences (no drops)
        kw["moe"] = dataclasses.replace(cfg.moe, n_experts=4, top_k=2,
                                        d_ff_expert=32, group_size=16,
                                        capacity_factor=8.0)
    if cfg.mla is not None:
        kw["mla"] = MLACfg(kv_lora_rank=32, q_lora_rank=0,
                           qk_nope_head_dim=16, qk_rope_head_dim=8,
                           v_head_dim=16)
    if cfg.ssm is not None:
        kw["ssm"] = dataclasses.replace(cfg.ssm, d_state=16, headdim=16,
                                        chunk_size=8)
    if cfg.encdec:
        kw["n_enc_layers"] = 2
        kw["n_layers"] = 4
        kw["enc_seq"] = 24
    return cfg.replace(**kw)


def concrete_batch(key, cfg: ModelConfig, *, batch: int, seq: int) -> Dict:
    """Small concrete batch for smoke tests."""
    ks = jax.random.split(key, 3)
    out = {
        "tokens": jax.random.randint(ks[0], (batch, seq), 0, cfg.vocab_size),
        "labels": jax.random.randint(ks[1], (batch, seq), 0, cfg.vocab_size),
    }
    if cfg.encdec:
        out["frames"] = jax.random.normal(
            ks[2], (batch, cfg.enc_seq, cfg.d_model), jnp.float32
        ).astype(jnp.dtype(cfg.dtype))
    return out
