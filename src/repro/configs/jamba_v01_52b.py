"""jamba-v0.1-52b [hybrid]: 32L, d=4096, attn:mamba 1:7 (attn at offset 4
of each 8-layer period), MoE 16e top-2 every other layer, 32H (kv=8),
d_ff=14336, vocab=65536. [arXiv:2403.19887; hf]

Note: Jamba v0.1 uses Mamba-1 internally; this framework uses the Mamba-2
SSD block (d_state=16 as in Jamba) — the TPU-native choice (chunked SSD maps
onto the MXU; see DESIGN.md hardware-adaptation notes).
"""
from repro.configs.base import LayerSpec, MoECfg, ModelConfig, SSMCfg


def config() -> ModelConfig:
    # 8-layer period: attn at offset 4, mamba elsewhere; MoE at odd offsets.
    period = tuple(
        LayerSpec("attn" if i == 4 else "mamba",
                  "moe" if i % 2 == 1 else "dense")
        for i in range(8))
    return ModelConfig(
        name="jamba-v0.1-52b", family="hybrid",
        d_model=4096, n_layers=32, n_heads=32, n_kv_heads=8, head_dim=128,
        d_ff=14336, vocab_size=65536,
        pattern=period,
        moe=MoECfg(n_experts=16, top_k=2, d_ff_expert=14336, group_size=512),
        ssm=SSMCfg(d_state=16, d_conv=4, expand=2, headdim=64, ngroups=1,
                   chunk_size=256),
        tie_embeddings=False,
    )
