"""whisper-small [audio]: enc-dec, 12 enc + 12 dec layers, d=768, 12H
(kv=12), d_ff=3072, vocab=51865. Conv/log-mel frontend is a STUB —
input_specs provides precomputed frame embeddings. [arXiv:2212.04356]
"""
from repro.configs.base import LayerSpec, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="whisper-small", family="audio",
        d_model=768, n_layers=24, n_enc_layers=12, encdec=True,
        n_heads=12, n_kv_heads=12, head_dim=64, d_ff=3072,
        vocab_size=51865,
        pattern=(LayerSpec("attn", "dense"),),
        norm_kind="layernorm", act="gelu", glu=False, qkv_bias=True,
        tie_embeddings=True, enc_seq=1500,
    )
