"""gemma2-2b [dense]: 26L, d=2304, 8H (kv=4, head_dim=256), d_ff=9216
(GeGLU), vocab=256000; local(4096)/global alternating; attn softcap 50,
final softcap 30; post-sublayer norms; tied + scaled embeddings.
[arXiv:2408.00118; hf]
"""
from repro.configs.base import LayerSpec, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="gemma2-2b", family="dense",
        d_model=2304, n_layers=26, n_heads=8, n_kv_heads=4, head_dim=256,
        d_ff=9216, vocab_size=256000,
        pattern=(LayerSpec("attn", "dense", window=4096),
                 LayerSpec("attn", "dense", window=0)),
        attn_softcap=50.0, final_softcap=30.0,
        act="gelu", glu=True, post_norm=True,
        tie_embeddings=True, embed_scale=True, rope_theta=1e4,
    )
